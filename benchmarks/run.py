"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure (cycle-accurate cost model on real
quantized weights), the Pallas kernel metrics, and the roofline aggregation
over whatever dry-run artifacts exist.  Output format: name,us_per_call,
derived (CSV).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    failures = 0
    from benchmarks import bench_kernels, bench_paper_tables, roofline
    sections = [("paper_tables", bench_paper_tables.run),
                ("kernels", bench_kernels.run),
                ("roofline", roofline.run)]
    print("name,us_per_call,derived")
    for name, fn in sections:
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0.0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
