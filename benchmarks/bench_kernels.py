"""Kernel-level benchmarks: SAC bit-plane matmul + kneaded integer GEMM.

Wall-times here are interpret-mode (CPU container) — meaningful only as
correctness-path cost; the TPU-relevant derived metrics are the HBM byte
ratios and the *executed-vs-dense tile-dot accounting* of the compacted
schedule (what the roofline and the CI regression gate consume).

The ``alexnet_sweep`` section kneads every AlexNet layer (weights trained
briefly from a fixed seed under the pinned jax — deterministic, see
:func:`alexnet_sweep`) and reports, per layer, the MXU passes the schedule
actually dispatches (``executed_tile_dots == occupancy nonzeros`` —
asserted here) against the dense grid's ``(B-1) * K/bk * N/bn``, plus the
paper's kneaded cycle ratio.

The ``sharded_sweep`` section partitions those same schedules over 4 model
shards (docs/DESIGN.md §5) and reports per-shard executed work and the
max/mean imbalance — deterministic, so ``shard_executed_max`` joins the CI
regression gate.  ``decode_sweep`` runs the kernel's decode-GEMV fast path
(docs/DESIGN.md §7) at batch 1/8/32 — tokens/s reported, the deterministic
tile-dot counts and max-error gated.  ``sharded_decode_sweep`` runs the LM
serving regime over sharded *stacked* schedules (docs/DESIGN.md §8):
batch 1/8 x shards 1/2/4 on a two-layer column-sparse projection bank,
per-shard work + imbalance reported, tile-dots/critical-path-load/max-err
gated.  ``moe_decode_sweep`` routes a fixed skewed trace through the
kneaded per-expert decode-GEMV path (docs/DESIGN.md §13): runtime-masked
executed tile-dots vs the dense expert slab, static expert imbalance, and
the emulated expert-parallel-vs-all-local max-err (0.0) are gated; the
derived string names how many experts the trace leaves active.
``serving`` runs the batched submit()/drain() front end on an
AlexNet-16 engine and reports per-request latency (wall clock: reported,
not gated).  ``serving_load_sweep`` replays a fixed Poisson request trace
against the LM engine's batch vs continuous schedulers (docs/DESIGN.md §9)
in deterministic tick space — latency-in-ticks p50/p95 and total ticks are
gated, wall tokens/s reported.

``--quick`` shrinks the raw-kernel shapes/bit sweeps to CI-smoke size (the
AlexNet sweep is metadata-only and always runs); ``--json PATH`` writes the
rows *with structured metrics* as JSON — the per-PR perf artifact that
``benchmarks/check_regression.py`` gates against the committed baseline.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cnn_weights, timed
from repro.core import knead, quantize
from repro.core.kneading import knead_padded, kneading_ratio
from repro.kernels.kneaded_gemm.ops import kneaded_gemm
from repro.kernels.kneaded_gemm.ref import pack_int4
from repro.kernels.sac_matmul.ops import m_block, sac_matmul_pallas
from repro.kernels.sac_matmul.ref import sac_matmul_ref

# (name, us_per_call, derived-string, structured metrics for the JSON gate)
BenchRow = Tuple[str, float, str, Dict[str, float]]


def _schedule_metrics(kw) -> Dict[str, float]:
    """Compacted-schedule accounting for one kneaded weight."""
    sched = kw.schedule
    occ_nnz = int(np.asarray(kw.occupancy_map()).sum())
    executed = sched.total_work
    # the bench is self-checking: the schedule must dispatch exactly the
    # occupied tiles — executed == occupancy nonzeros, NOT (B-1)*K/bk*N/bn
    assert executed == occ_nnz, (executed, occ_nnz)
    dense = sched.dense_work(kw.bits)
    return {
        "executed_tile_dots": executed,
        "dense_tile_dots": dense,
        "occupancy_nonzeros": occ_nnz,
        "tile_dot_skip_frac": 1.0 - executed / max(1, dense),
        "metadata_bytes": kw.metadata_bytes(),
        "bytes_vs_bf16": kw.packed_bytes() / kw.dense_bf16_bytes(),
    }


def sac_rows(quick: bool) -> List[BenchRow]:
    rows: List[BenchRow] = []
    key = jax.random.PRNGKey(0)
    m, k, n = (8, 256, 128) if quick else (8, 1024, 512)
    w = jax.random.normal(key, (k, n)) * 0.02
    a = jax.random.normal(jax.random.PRNGKey(1), (m, k))

    for bits in (4, 8) if quick else (4, 8, 16):
        kw = knead(w, bits=bits, ks=256, n_block=128)
        us, out = timed(lambda: sac_matmul_pallas(a, kw, bm=8), repeats=1)
        ref = sac_matmul_ref(a, kw)
        err = float(jnp.max(jnp.abs(out - ref)))
        met = _schedule_metrics(kw)
        met["max_err"] = err
        rows.append((
            f"kernel/sac_matmul_b{bits}", us,
            f"bytes_vs_bf16={met['bytes_vs_bf16']:.3f} "
            f"tile_dots={met['executed_tile_dots']}/{met['dense_tile_dots']} "
            f"max_err={err:.1e}", met))

    qt8 = quantize(w, bits=8)
    us, out8 = timed(lambda: kneaded_gemm(a, qt8.q, qt8.scale.reshape(1, -1)),
                     repeats=1)
    err8 = float(jnp.max(jnp.abs(out8 - a @ (qt8.q * qt8.scale))))
    rows.append(("kernel/kneaded_gemm_int8", us,
                 f"weight_bytes_vs_bf16=0.500 max_err={err8:.1e}",
                 {"max_err": err8}))

    qt4 = quantize(w, bits=4)
    packed = pack_int4(qt4.q)
    us, out4 = timed(lambda: kneaded_gemm(a, packed, qt4.scale.reshape(1, -1),
                                          packed4=True), repeats=1)
    err4 = float(jnp.max(jnp.abs(out4 - a @ (qt4.q * qt4.scale))))
    rows.append(("kernel/kneaded_gemm_int4", us,
                 f"weight_bytes_vs_bf16=0.250 max_err={err4:.1e}",
                 {"max_err": err4}))

    # dense bf16 reference timing (XLA, not interpret — not comparable, but
    # shows the oracle cost scale)
    us, _ = timed(lambda: a.astype(jnp.bfloat16) @ w.astype(jnp.bfloat16))
    rows.append(("kernel/dense_bf16_xla_ref", us, "baseline_matmul", {}))
    return rows


def _blocksparse_fc8(params, ks: int) -> jax.Array:
    """fc8 with its 50% lowest-L2 (ks x 128) blocks pruned — the shared
    block-sparse specimen of both gated sweeps (the pruning recipe must not
    drift between the unsharded and sharded baseline rows)."""
    from repro.models import cnn

    w = jnp.asarray(cnn.weight_matrices(params)["fc8"])     # [4096, 1024]
    kb, nb = w.shape[0] // ks, w.shape[1] // 128
    blocks = w.reshape(kb, ks, nb, 128)
    norms = jnp.sqrt(jnp.sum(blocks ** 2, axis=(1, 3)))     # [kb, nb]
    mask = norms >= jnp.median(norms)
    return (blocks * mask[:, None, :, None]).reshape(w.shape)


def alexnet_sweep(bits: int = 8, ks: int = 256,
                  cycle_ks: int = 16) -> List[BenchRow]:
    """Per-layer compacted-schedule accounting on trained AlexNet weights.

    Metadata-only (no kernel execution): kneads each conv/fc im2col matrix
    and reports executed vs dense tile-dots plus the Fig 11 kneaded cycle
    ratio at hardware stride ``cycle_ks``.  Deterministic: ``cnn_weights``
    trains briefly from a fixed seed under the *pinned* jax version (~3s on
    a cache miss, cached to benchmarks/artifacts/ afterwards), so fresh CI
    checkouts reproduce the same weights the committed baseline was built
    from; the 10% gate tolerance absorbs any cross-ISA float drift.
    """
    from repro.models import cnn

    rows: List[BenchRow] = []
    params = cnn_weights("alexnet")
    for lname, w in cnn.weight_matrices(params).items():
        w = jnp.asarray(w)
        kw = knead_padded(w, bits=bits, ks=ks)
        met = _schedule_metrics(kw)
        q = quantize(w, bits=bits, axis=None).q
        k16 = (q.shape[0] // cycle_ks) * cycle_ks
        met["cycle_ratio"] = float(kneading_ratio(q[:k16], bits, cycle_ks))
        rows.append((
            f"alexnet_sweep/{lname}", 0.0,
            f"tile_dots={met['executed_tile_dots']}/{met['dense_tile_dots']} "
            f"skip={100 * met['tile_dot_skip_frac']:.1f}% "
            f"cycle_ratio={100 * met['cycle_ratio']:.1f}% "
            f"shape={tuple(w.shape)}", met))
    total_exec = sum(r[3]["executed_tile_dots"] for r in rows)
    total_dense = sum(r[3]["dense_tile_dots"] for r in rows)
    rows.append((
        "alexnet_sweep/total", 0.0,
        f"tile_dots={total_exec}/{total_dense} "
        f"skip={100 * (1 - total_exec / total_dense):.1f}%",
        {"executed_tile_dots": total_exec, "dense_tile_dots": total_dense}))

    # Dense trained weights occupy every (ks x n_block) tile — the schedule
    # degenerates to the dense grid there (executed == dense, as the rows
    # above show).  Block-structured sparsity at the kernel's own skip
    # granularity is where compaction bites: prune the 50% lowest-L2
    # (256 x 128) blocks of fc8 and the schedule dispatches ~half the MXU
    # passes, which the CI gate then pins.
    kw = knead_padded(_blocksparse_fc8(params, ks), bits=bits, ks=ks)
    met = _schedule_metrics(kw)
    rows.append((
        "alexnet_sweep/fc8_blocksparse50", 0.0,
        f"tile_dots={met['executed_tile_dots']}/{met['dense_tile_dots']} "
        f"skip={100 * met['tile_dot_skip_frac']:.1f}% "
        f"(block-pruned at the kernel's ks x n_block skip granularity)", met))
    rows += _act_skip_rows(params, bits=bits, ks=ks)
    return rows


def _relu_sparse_trace(seed: int, k: int, ks: int,
                       dead_frac: float = 0.5) -> jax.Array:
    """A deterministic decode-GEMV activation row with ReLU + dead-channel
    structure: elementwise ReLU sparsity alone (~50% zeros) never empties a
    ``ks``-wide K-tile, so tile-granular runtime skip sees nothing — the
    payoff comes from *dead channels* (whole feature maps stuck at zero in
    trained ReLU nets), modeled here by zeroing ``dead_frac`` of the K-tiles
    wholesale."""
    kk = jax.random.split(jax.random.PRNGKey(seed), 2)
    a = jax.nn.relu(jax.random.normal(kk[0], (1, k)))
    nk = k // ks
    alive = (jax.random.uniform(kk[1], (nk,)) >= dead_frac).astype(a.dtype)
    return (a.reshape(1, nk, ks) * alive[None, :, None]).reshape(1, k)


def _act_skip_metrics(kw, a: jax.Array) -> Dict[str, float]:
    """Two-sided (weight x activation) tile-dot accounting for one decode
    row.  NOT :func:`_schedule_metrics`: with the runtime intersection the
    executed count sits *below* the occupancy nonzeros, which is the point."""
    from repro.core import activation_occupancy as actocc

    pres = actocc.ktile_presence(a, kw.ks)
    mask = actocc.work_mask(kw.schedule.counts, kw.schedule.ktile_ids, pres)
    executed = int(np.asarray(jnp.sum(mask)))
    weight_only = int(kw.schedule.total_work)
    dense = int(kw.schedule.dense_work(kw.bits))
    return {
        "executed_tile_dots": executed,
        "weight_tile_dots": weight_only,
        "dense_tile_dots": dense,
        "act_skip_frac": 1.0 - executed / max(1, weight_only),
        "tile_dot_skip_frac": 1.0 - executed / max(1, dense),
    }


def _act_skip_rows(params, bits: int, ks: int) -> List[BenchRow]:
    """Activation-intersected rows on trained AlexNet fc layers
    (docs/DESIGN.md §12).  Dense trained weights occupy every tile, so the
    weight-only rows above report skip=0.0% on the fc layers — the honesty
    gap of one-sided kneading.  Against a ReLU-sparse decode trace the
    runtime intersection drops the dead channels' tile-dots, so these rows
    report ``tile_dot_skip_frac > 0`` on the SAME dense weights (asserted,
    plus strict executed < weight-only — and bit-exactness on the row that
    runs the masked kernel); ``act_skip_frac`` joins the higher-is-better
    CI gate."""
    from repro.core.sac import sac_matmul
    from repro.models import cnn

    rows: List[BenchRow] = []
    wmats = cnn.weight_matrices(params)
    # (row suffix, weight, run the masked kernel?) — fc10 is small enough
    # to pay interpret-mode kernel parity at bench time; fc8 rows are
    # accounting-only (the test wall owns their bit-exactness)
    cases = (("fc8_actskip", jnp.asarray(wmats["fc8"]), 31, False),
             ("fc8_blocksparse50_actskip", _blocksparse_fc8(params, ks),
              31, False),
             # seed 30 leaves fc10's 4 K-tiles half alive — a *partial*
             # mask, so the kernel row exercises mixed survive/drop steps
             ("fc10_actskip", jnp.asarray(wmats["fc10"]), 30, True))
    for suffix, w, seed, run_kernel in cases:
        kw = knead_padded(w, bits=bits, ks=ks)
        a = _relu_sparse_trace(seed, kw.k, ks)
        met = _act_skip_metrics(kw, a)
        # the two-sided accounting must actually bite on dense weights
        assert met["executed_tile_dots"] < met["weight_tile_dots"], \
            (suffix, met)
        assert met["tile_dot_skip_frac"] > 0.0, (suffix, met)
        derived = (
            f"tile_dots={met['executed_tile_dots']}"
            f"/{met['weight_tile_dots']}(w-only)"
            f"/{met['dense_tile_dots']}(dense) "
            f"act_skip={100 * met['act_skip_frac']:.1f}%")
        if run_kernel:
            us, out = timed(
                lambda: sac_matmul_pallas(a, kw, skip_activations=True),
                repeats=1)
            ref = np.asarray(sac_matmul(a, kw, impl="planes"))
            err = float(np.max(np.abs(
                np.asarray(out)[:, :kw.logical_n] - ref)))
            assert err == 0.0, (suffix, err)     # masked walk is bit-exact
            met["max_err"] = err
            derived += f" max_err={err:.1e}"
        else:
            us = 0.0
        rows.append((f"alexnet_sweep/{suffix}", us, derived, met))
    return rows


def sharded_sweep(num_shards: int = 4, bits: int = 8,
                  ks: int = 256) -> List[BenchRow]:
    """Per-layer N-sharded schedule accounting on trained AlexNet weights.

    Metadata-only, like :func:`alexnet_sweep`: shards every layer's
    compacted schedule over ``num_shards`` (a plain shard count — no mesh
    needed for load accounting) and reports each device's executed work.
    ``shard_executed_max`` is the critical-path load (the slowest device
    gates the layer), ``shard_imbalance`` = max/mean; both deterministic
    from the committed weights, so the regression gate pins the max.
    """
    from repro.core.schedule import shard_schedule
    from repro.models import cnn

    rows: List[BenchRow] = []
    params = cnn_weights("alexnet")
    for lname, w in cnn.weight_matrices(params).items():
        kw = knead_padded(jnp.asarray(w), bits=bits, ks=ks)
        skw = shard_schedule(kw, num_shards)
        imb = skw.imbalance()
        met = {
            "executed_tile_dots": skw.total_work,
            "dense_tile_dots": skw.dense_work(),
            "shard_executed_max": imb["max"],
            "shard_imbalance": imb["imbalance"],
        }
        rows.append((
            f"sharded_sweep/{lname}@{num_shards}", 0.0,
            f"shard_work={imb['shard_work']} "
            f"imbalance={imb['imbalance']:.2f}", met))

    # block-sparse fc8 (the alexnet_sweep row where compaction bites):
    # pruning is occupancy-blind to shard boundaries, so this is the
    # imbalance stress case the report exists for
    skw = shard_schedule(
        knead_padded(_blocksparse_fc8(params, ks), bits=bits, ks=ks),
        num_shards)
    imb = skw.imbalance()
    rows.append((
        f"sharded_sweep/fc8_blocksparse50@{num_shards}", 0.0,
        f"shard_work={imb['shard_work']} imbalance={imb['imbalance']:.2f}",
        {"executed_tile_dots": skw.total_work,
         "shard_executed_max": imb["max"],
         "shard_imbalance": imb["imbalance"]}))
    return rows


def decode_sweep(quick: bool) -> List[BenchRow]:
    """Decode-GEMV rows: the SAC kernel in the LM decode regime (M = batch).

    Runs ``sac_matmul_pallas`` at batch 1/8/32 on a fixed-seed LM-projection
    -sized kneaded weight — the ops-layer fast path shrinks the M block to
    the 8-row sublane floor instead of padding a one-token step to the full
    streamed block.  ``tokens_per_s`` is interpret-mode wall clock (reported,
    not gated); the deterministic ``executed_tile_dots`` and ``max_err`` of
    each row join the CI regression gate, so a change that inflates the
    decode path's dispatched MXU passes (or its accuracy) fails the build.
    """
    rows: List[BenchRow] = []
    k, n = (256, 128) if quick else (1024, 512)
    w = jax.random.normal(jax.random.PRNGKey(11), (k, n)) * 0.02
    kw = knead(w, bits=8, ks=256, n_block=128)
    for batch in (1, 8, 32):
        a = jax.random.normal(jax.random.PRNGKey(12), (batch, k))
        us, out = timed(lambda: sac_matmul_pallas(a, kw), repeats=1)
        err = float(jnp.max(jnp.abs(out - sac_matmul_ref(a, kw))))
        tok_s = batch / (us * 1e-6)
        bm_eff = m_block(batch)     # the fast path the kernel actually ran
        met = _schedule_metrics(kw)
        met["max_err"] = err
        met["tokens_per_s"] = tok_s          # wall clock: not gated
        rows.append((
            f"decode_sweep/gemv_b{batch}", us,
            f"tok_s={tok_s:.1f} bm_eff={bm_eff} "
            f"tile_dots={met['executed_tile_dots']}/{met['dense_tile_dots']} "
            f"max_err={err:.1e}", met))

    # activation-skip decode row (docs/DESIGN.md §12): an LM-projection-
    # sized kneaded weight driven by a ReLU-sparse single-token trace
    # through the masked kernel walk — executed tile-dots drop strictly
    # below the weight-only schedule at zero error (both asserted;
    # act_skip_frac joins the higher-is-better CI gate).  Fixed at
    # K=1024 even under --quick: the quick shapes have a single K-tile,
    # where tile-granular skip is all-or-nothing
    from repro.core.sac import sac_matmul

    k, n = 1024, 512
    w = jax.random.normal(jax.random.PRNGKey(11), (k, n)) * 0.02
    kw = knead(w, bits=8, ks=256, n_block=128)
    a = _relu_sparse_trace(32, k, 256)
    met = _act_skip_metrics(kw, a)
    us, out = timed(lambda: sac_matmul_pallas(a, kw, skip_activations=True),
                    repeats=1)
    err = float(np.max(np.abs(np.asarray(out)
                              - np.asarray(sac_matmul(a, kw,
                                                      impl="planes")))))
    assert err == 0.0, err
    assert met["executed_tile_dots"] < met["weight_tile_dots"], met
    met["max_err"] = err
    met["tokens_per_s"] = 1 / (us * 1e-6)        # wall clock: not gated
    rows.append((
        "decode_sweep/gemv_b1_actskip", us,
        f"tok_s={met['tokens_per_s']:.1f} "
        f"tile_dots={met['executed_tile_dots']}"
        f"/{met['weight_tile_dots']}(w-only) "
        f"act_skip={100 * met['act_skip_frac']:.1f}% max_err={err:.1e}", met))
    return rows


def sharded_decode_sweep(quick: bool) -> List[BenchRow]:
    """Sharded decode-GEMV rows: the LM serving regime over a model mesh.

    A fixed-seed stacked [L, K, N] projection bank (two layers, 16 N-tiles,
    whole column blocks zeroed per layer so the *low* slabs hold all the
    live tiles — the contiguous split's worst case) is kneaded per layer
    (``knead_stacked``) and sharded at 1/2/4 under both tile->shard
    partitionings (``shard_stacked_schedule(..., partition=...)``,
    docs/DESIGN.md §11), then decoded through the scan-sliced serial shard
    walk at batch 1/8 — the exact per-layer kernel programs the mesh
    launches, minus the device transport, so the rows run on the single-CPU
    CI container.  ``tokens_per_s`` is the *unsharded* interpret wall clock
    scaled by the critical-path share ``shard_executed_max / total_work``
    (a serial walk cannot show parallel speedup directly, and the S-call
    serial walk pays per-launch interpret overhead a real mesh would not;
    the scaling uses the same deterministic accounting the gate pins) —
    reported, not gated.
    The deterministic ``executed_tile_dots``, ``shard_executed_max``
    (critical-path load of the most-loaded device), ``shard_imbalance``
    (~1.0 baselined on the balanced rows), and ``max_err`` vs the unsharded
    stacked kernel on BOTH the pallas and planes paths (bit-exact: 0.0)
    join the CI regression gate.  The balanced@4 rows are additionally
    self-checking: imbalance <= 1.15, modeled tokens/s >= the shards=1 row,
    max_err == 0.0 — the ISSUE's acceptance criterion, asserted at bench
    time.
    """
    from repro.core.kneading import knead_stacked
    from repro.core.sac import sac_matmul
    from repro.core.schedule import shard_stacked_schedule

    rows: List[BenchRow] = []
    k = 256 if quick else 1024
    n, layers = 2048, 2          # 16 N-tiles: enough grain to pack at S=4
    w = jax.random.normal(jax.random.PRNGKey(21), (layers, k, n)) * 0.02
    # structured column sparsity, different per layer: layer 0 keeps N-tiles
    # 0-7 (first half of its output channels), layer 1 tiles 0-11 (three
    # quarters) — contiguous slabs pile all work on the low shards
    w = w.at[0, :, n // 2:].set(0.0)
    w = w.at[1, :, (3 * n) // 4:].set(0.0)
    stacked = knead_stacked(w, bits=8)

    def scan_decode(a, kw_stacked, impl="pallas"):
        def body(carry, kw_l):
            return carry, sac_matmul(a, kw_l, impl=impl)
        return jax.lax.scan(body, 0, kw_stacked)[1]

    base_us: Dict[int, float] = {}
    base_tok_s: Dict[int, float] = {}
    for batch in (1, 8):
        a = jax.random.normal(jax.random.PRNGKey(22), (batch, k))
        base_us[batch], _ = timed(lambda: scan_decode(a, stacked), repeats=1)
        base_tok_s[batch] = batch / (base_us[batch] * 1e-6)
    for shards, partition in ((1, "contiguous"), (2, "contiguous"),
                              (2, "balanced"), (4, "contiguous"),
                              (4, "balanced")):
        ssk = shard_stacked_schedule(stacked, shards, partition=partition)
        imb = ssk.imbalance()
        for batch in (1, 8):
            a = jax.random.normal(jax.random.PRNGKey(22), (batch, k))
            us, out = timed(lambda: scan_decode(a, ssk), repeats=1)
            # bit-exact against the unsharded stack on BOTH reference paths
            err = max(
                float(jnp.max(jnp.abs(out - scan_decode(a, stacked)))),
                float(jnp.max(jnp.abs(
                    out - scan_decode(a, stacked, impl="planes")))))
            # modeled critical-path throughput: the unsharded wall clock
            # scaled by the most-loaded shard's share of the executed work
            crit = imb["max"] / max(1, ssk.total_work)
            tok_s = batch / (base_us[batch] * 1e-6 * max(crit, 1e-9))
            if partition == "balanced":
                assert err == 0.0, (shards, batch, err)
                if shards == 4:
                    assert imb["imbalance"] <= 1.15, imb
                    assert tok_s >= base_tok_s[batch], \
                        (tok_s, base_tok_s[batch])
            met = {
                "executed_tile_dots": ssk.total_work,
                "dense_tile_dots": ssk.dense_work(),
                "shard_executed_max": imb["max"],
                "shard_imbalance": imb["imbalance"],
                "max_layer_imbalance": imb.get("max_layer_imbalance", 1.0),
                "max_err": err,
                "tokens_per_s": tok_s,       # wall-clock-derived: not gated
            }
            rows.append((
                f"sharded_decode_sweep/b{batch}@s{shards}/{partition}", us,
                f"tok_s={tok_s:.1f} shard_work={imb['shard_work']} "
                f"imbalance={imb['imbalance']:.2f} max_err={err:.1e}", met))
    return rows


def moe_decode_sweep(quick: bool) -> List[BenchRow]:
    """Kneaded expert-parallel MoE decode rows (docs/DESIGN.md §13).

    A fixed-seed SKEWED expert bank (8 experts; expert e keeps only a
    shrinking prefix of its N-tiles, so the static per-expert work table is
    heavily imbalanced) is kneaded per expert (``knead_stacked`` on
    [E, K, N]) and driven through the routed per-expert decode-GEMV path
    (``models.blocks._dispatch_compute_kneaded``) on a HANDCRAFTED skewed
    routing trace — deterministic token->expert assignments that leave half
    the experts without a single routed token.  The two-sided skip then
    turns routing sparsity into skipped MXU passes: an expert with no
    routed tokens gathers only the zero pad row, its activation presence is
    all-zero, and its entire schedule walk is masked off.

    Gated metrics (CI): ``executed_tile_dots`` (runtime-masked passes,
    asserted STRICTLY below the dense expert slab's tile-dot count — the
    ISSUE acceptance), ``expert_imbalance`` (static work-table max/mean —
    the load-skew signal expert placement has to live with), and
    ``max_err`` — the emulated expert-parallel run (per-shard expert slices
    dispatched at their global offsets, partials summed like the mesh
    psum) against the all-experts-local oracle, asserted == 0.0 at bench
    time for EP ∈ {2, 4}.  Reported honestly: the derived string names how
    many experts the trace leaves active — a capped trace *overstates*
    skip on traffic that actually spreads across all experts.
    """
    from repro.configs.base import ModelConfig
    from repro.core import activation_occupancy
    from repro.core.kneading import knead_stacked
    from repro.models import blocks

    e, bits = 8, 8
    k = 256 if quick else 512
    f = 256 if quick else 512
    cfg = ModelConfig(name="bench-moe", family="moe", num_experts=e,
                      top_k=2, moe_dff=f, d_model=k, activation="gelu",
                      impl="pallas", activation_skip=True)
    wi = jax.random.normal(jax.random.PRNGKey(31), (e, k, f)) * 0.02
    wo = jax.random.normal(jax.random.PRNGKey(32), (e, f, k)) * 0.02
    # skewed static occupancy: expert i keeps ~(e - i)/e of its N-tiles
    for i in range(e):
        keep_i = max(1, ((e - i) * f) // e)
        wi = wi.at[i, :, keep_i:].set(0.0)
        keep_o = max(1, ((e - i) * k) // e)
        wo = wo.at[i, :, keep_o:].set(0.0)
    kwi = knead_stacked(wi, bits=bits)
    kwo = knead_stacked(wo, bits=bits)
    table = kwi.work_table() + kwo.work_table()          # static [E] load
    expert_imbalance = float(table.max() / max(table.mean(), 1e-9))
    dense = e * (kwi.schedule.dense_work(bits)
                 + kwo.schedule.dense_work(bits))

    # handcrafted skewed routing traces: experts 4..7 never see a token
    traces = {
        1: jnp.asarray([[0, 1]], jnp.int32),
        8: jnp.asarray([[0, 1], [0, 2], [1, 2], [0, 1],
                        [2, 3], [0, 1], [1, 3], [0, 2]], jnp.int32),
    }

    def dispatch(x2d, eids, gates, kwi_, kwo_, e_offset, cap):
        return blocks._dispatch_compute_kneaded(
            x2d, eids, gates, kwi_, kwo_, cfg=cfg, e_offset=e_offset,
            cap=cap, dtype=jnp.float32)

    rows: List[BenchRow] = []
    for batch, eids in traces.items():
        active = int(np.unique(np.asarray(eids)).size)
        if active < e:
            # bench honesty (satellite): a capped trace inflates skip
            print(f"[moe_decode_sweep] b{batch}: routing trace caps "
                  f"active experts at {active}/{e} — skip fractions below "
                  f"overstate a uniformly-routed workload")
        gates = jnp.full(eids.shape, 1.0 / eids.shape[1], jnp.float32)
        x2d = jax.random.normal(jax.random.PRNGKey(34), (batch, k))
        cap = blocks._capacity(batch, cfg)
        # skip accounting from exactly ONE dispatch — the counters are
        # process-global and timed() adds a warmup launch on top of its
        # repeats, which would multiply executed_tile_dots per run
        activation_occupancy.reset_skip_stats()
        y_local = dispatch(x2d, eids, gates, kwi, kwo, 0, cap)
        jax.block_until_ready(y_local)
        stats = activation_occupancy.skip_stats()
        executed = int(stats["executed_tile_dots"])
        us, _ = timed(
            lambda: dispatch(x2d, eids, gates, kwi, kwo, 0, cap),
            repeats=1)
        # the ISSUE acceptance: the routed kneaded path executes strictly
        # fewer tile-dots than the capacity-padded dense expert slab
        assert 0 < executed < dense, (executed, dense)

        # emulated expert parallelism: per-shard expert slices at their
        # global offsets, partials summed exactly like the mesh psum
        err = 0.0
        for shards in (2, 4):
            e_loc = e // shards
            y_ep = sum(
                dispatch(
                    x2d, eids, gates,
                    jax.tree.map(lambda a, s=s: a[s * e_loc:
                                                  (s + 1) * e_loc], kwi),
                    jax.tree.map(lambda a, s=s: a[s * e_loc:
                                                  (s + 1) * e_loc], kwo),
                    s * e_loc, cap)
                for s in range(shards))
            err = max(err, float(jnp.max(jnp.abs(y_ep - y_local))))
        assert err == 0.0, err

        tok_s = batch / (us * 1e-6)
        met = {
            "executed_tile_dots": executed,
            "weight_tile_dots": int(stats["weight_tile_dots"]),
            "dense_tile_dots": dense,
            "expert_imbalance": expert_imbalance,
            "active_experts": active,
            "max_err": err,
            "tokens_per_s": tok_s,           # wall clock: not gated
        }
        rows.append((
            f"moe_decode_sweep/b{batch}_e{e}_top2", us,
            f"tok_s={tok_s:.1f} tile_dots={executed}/{dense}(dense) "
            f"active={active}/{e} imbalance={expert_imbalance:.2f} "
            f"max_err={err:.1e}", met))
    return rows


def serving_rows(quick: bool) -> List[BenchRow]:
    """Batched submit()/drain() front end: per-request latency on a kneaded
    AlexNet-16 engine (int path — the production CPU impl; wall clock, so
    reported but not gated)."""
    import dataclasses

    from repro.inference.cnn_engine import CNNServingConfig, CNNServingEngine
    from repro.models import cnn

    cfg = dataclasses.replace(cnn.CNN_ZOO["alexnet"], image_size=16)
    # init (not the cached trained-at-32 weights): the 16px fc dims differ,
    # and latency is what this row measures, not schedule statistics
    params = cnn.init(jax.random.PRNGKey(0), cfg)
    n_req = 6 if quick else 12
    eng = CNNServingEngine(cfg, params,
                           CNNServingConfig(impl="int", buckets=(2, 4)))
    xs = jax.random.normal(jax.random.PRNGKey(1),
                           (n_req, cfg.image_size, cfg.image_size, 3))
    eng.logits(xs[:4])                       # warm the bucket-4 compile
    eng.logits(xs[:2])                       # ... and bucket-2
    for i in range(n_req):
        eng.submit(xs[i])
    eng.drain()
    stats = eng.latency_stats()
    return [(
        "serving/batched_alexnet16_int8", stats["mean_ms"] * 1e3,
        f"req={stats['requests']} p50={stats['p50_ms']:.1f}ms "
        f"p95={stats['p95_ms']:.1f}ms fill={stats['mean_batch_fill']:.2f}",
        {"requests": stats["requests"],
         "mean_batch_fill": stats["mean_batch_fill"]})]


def serving_load_sweep(quick: bool) -> List[BenchRow]:
    """Latency under load: batch-synchronous drain() vs the continuous
    scheduler on an identical Poisson request trace.

    Arrivals are generated in **tick space** — the engines' virtual-launch
    clock (+1 per jitted prefill/decode) — with a fixed seed, so the whole
    sweep is deterministic: per-request ``latency_ticks`` p50/p95 and the
    trace's ``total_ticks`` join the CI regression gate, while wall-clock
    tokens/s is reported only.  Each rate drives both engines through the
    same (arrival tick, prompt len, budget) trace: the batch server drains
    a wave whenever requests are waiting (new arrivals during a wave queue
    for the next one — the wave barrier this sweep exists to price), the
    continuous server admits at step granularity.  The bench self-checks
    the ISSUE acceptance bar: at the highest arrival rate, continuous p95
    must not exceed batch p95.
    """
    from repro.configs.registry import get_config
    from repro.inference.engine import ServingConfig, ServingEngine
    from repro.models.lm import LanguageModel

    cfg = get_config("smollm-360m", smoke=True)
    params = LanguageModel(cfg).init(jax.random.PRNGKey(0))
    n_req = 8 if quick else 16
    plens = [6, 10, 4, 6]
    budgets = [4, 8, 2, 6]
    prompts = [jax.random.randint(jax.random.PRNGKey(100 + i),
                                  (plens[i % 4],), 0, cfg.vocab_size)
               for i in range(n_req)]

    def make_engine(scheduler):
        return ServingEngine(cfg, params, ServingConfig(
            max_len=32, impl="int", knead_min_dim=8, buckets=(1, 2, 4),
            scheduler=scheduler, max_inflight=4, kv_block=16))

    def trace_for(lam):
        rng = np.random.default_rng(1234 + lam)
        gaps = rng.poisson(lam, size=n_req)
        return np.cumsum(gaps).tolist()

    def drive_batch(eng, arrivals):
        i = 0
        while i < n_req:
            eng.ticks = max(eng.ticks, arrivals[i])
            while i < n_req and arrivals[i] <= eng.ticks:
                h = eng.submit(prompts[i], budgets[i % 4])
                h._req.submit_tick = arrivals[i]   # true arrival, not drain
                i += 1
            eng.drain()
        eng.drain()

    def drive_continuous(eng, arrivals):
        i = 0
        busy = False
        while i < n_req or busy:
            while i < n_req and arrivals[i] <= eng.ticks:
                h = eng.submit(prompts[i], budgets[i % 4])
                h._req.submit_tick = arrivals[i]
                i += 1
            if not busy and i < n_req and not eng._pending:
                eng.ticks = arrivals[i]            # idle: jump to arrival
                continue
            busy = eng.scheduler_step()

    rows: List[BenchRow] = []
    p95_by = {}
    for lam in (12, 6, 2):                         # mean interarrival ticks
        arrivals = trace_for(lam)
        for sched, drive in (("batch", drive_batch),
                             ("continuous", drive_continuous)):
            eng = make_engine(sched)
            t0 = time.perf_counter()     # stateful drive: no warmup call
            drive(eng, arrivals)
            us = (time.perf_counter() - t0) * 1e6
            lat = np.array([r["latency_ticks"] for r in eng._request_log])
            assert lat.size == n_req, (sched, lam, lat.size)
            toks = sum(budgets[i % 4] for i in range(n_req))
            met = {
                "p50_latency_ticks": float(np.percentile(lat, 50)),
                "p95_latency_ticks": float(np.percentile(lat, 95)),
                "total_ticks": float(eng.ticks),
                "tokens_per_s": toks / (us * 1e-6),   # wall: not gated
            }
            p95_by[(sched, lam)] = met["p95_latency_ticks"]
            rows.append((
                f"serving_load_sweep/{sched}@lam{lam}", us,
                f"p50={met['p50_latency_ticks']:.0f} "
                f"p95={met['p95_latency_ticks']:.0f}t "
                f"total={eng.ticks}t tok_s={met['tokens_per_s']:.1f}", met))
    # the acceptance bar: continuous beats the wave barrier at peak load
    assert p95_by[("continuous", 2)] <= p95_by[("batch", 2)], p95_by
    return rows


def serving_fault_sweep(quick: bool) -> List[BenchRow]:
    """Goodput + tail latency under injected fault rates (~0/1/5% of
    decode launch attempts) on the continuous scheduler with the fault
    policy armed (docs/DESIGN.md §10).

    Deterministic end to end: tick-space Poisson arrivals with a fixed
    seed, fault injection at fixed decode-attempt indices, and
    ``retry_backoff_s=0`` so recovery scheduling never consults the wall
    clock — ``failed_requests``, ``retries`` and the tick-space latency
    percentiles are exact across runs and join the CI regression gate
    (a fault-handling change that starts losing requests or retrying
    more trips the gate).  Wall-clock goodput (completed tokens/s, the
    paid-for metric under faults) is reported but not gated.  The rate-0
    row runs with the policy armed too, so it prices the NaN-guard +
    watchdog overhead against serving_load_sweep's unguarded continuous
    rows.
    """
    from repro.configs.registry import get_config
    from repro.inference.engine import ServingConfig, ServingEngine
    from repro.inference.resilience import (EngineFaultInjector,
                                            ServingFaultPolicy)
    from repro.models.lm import LanguageModel

    cfg = get_config("smollm-360m", smoke=True)
    params = LanguageModel(cfg).init(jax.random.PRNGKey(0))
    n_req = 8 if quick else 16
    plens = [6, 10, 4, 6]
    budgets = [4, 8, 2, 6]
    prompts = [jax.random.randint(jax.random.PRNGKey(100 + i),
                                  (plens[i % 4],), 0, cfg.vocab_size)
               for i in range(n_req)]
    rng = np.random.default_rng(4321)
    arrivals = np.cumsum(rng.poisson(4, size=n_req)).tolist()
    # ~rate of the ≈30 (quick) / ≈65 decode attempts the trace generates;
    # fixed indices, NOT sampled, so every run injects identically
    fault_plans = (("0pct", ()),
                   ("1pct", (8,) if quick else (25,)),
                   ("5pct", (5, 12, 19) if quick else (5, 12, 19, 33, 47)))

    rows: List[BenchRow] = []
    for label, fail_steps in fault_plans:
        pol = ServingFaultPolicy(
            max_retries=3, retry_backoff_s=0.0,
            injector=(EngineFaultInjector(fail_decode_steps=fail_steps)
                      if fail_steps else None))
        eng = ServingEngine(cfg, params, ServingConfig(
            max_len=32, impl="int", knead_min_dim=8, buckets=(1, 2, 4),
            scheduler="continuous", max_inflight=4, kv_block=16,
            fault_policy=pol))
        handles = []
        i = 0
        busy = False
        t0 = time.perf_counter()
        while i < n_req or busy:
            while i < n_req and arrivals[i] <= eng.ticks:
                h = eng.submit(prompts[i], budgets[i % 4])
                h._req.submit_tick = arrivals[i]
                handles.append(h)
                i += 1
            if not busy and i < n_req and not eng._pending:
                eng.ticks = arrivals[i]            # idle: jump to arrival
                continue
            busy = eng.scheduler_step()
        wall_s = time.perf_counter() - t0
        stats = eng.latency_stats()
        done_tokens = sum(h._req.num_tokens for h in handles
                          if h.state == "done")
        lat = np.array([r["latency_ticks"] for r in eng._request_log])
        met = {
            "failed_requests": stats.get("failed_requests", 0),
            "retries": stats.get("retries", 0),
            "p95_latency_ticks": float(np.percentile(lat, 95)),
            "total_ticks": float(eng.ticks),
            "goodput_tokens_per_s": done_tokens / wall_s,   # wall: not gated
        }
        if not fail_steps:      # clean trace: the policy must be invisible
            assert met["retries"] == 0 and met["failed_requests"] == 0, met
        rows.append((
            f"serving_fault_sweep/continuous@{label}", wall_s * 1e6,
            f"done={lat.size}/{n_req} retries={met['retries']} "
            f"failed={met['failed_requests']} "
            f"p95={met['p95_latency_ticks']:.0f}t "
            f"goodput={met['goodput_tokens_per_s']:.1f}tok/s", met))
    return rows


def run(quick: bool = False) -> List[BenchRow]:
    return (sac_rows(quick) + alexnet_sweep() + sharded_sweep()
            + decode_sweep(quick) + sharded_decode_sweep(quick)
            + moe_decode_sweep(quick)
            + serving_rows(quick) + serving_load_sweep(quick)
            + serving_fault_sweep(quick))


def main(argv: Optional[List[str]] = None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--quick", action="store_true",
                        help="CI smoke mode: small shapes, fewer bit widths")
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="also write rows (with metrics) as JSON to PATH")
    args = parser.parse_args(argv)
    rows = run(quick=args.quick)
    from benchmarks.common import print_rows
    print_rows([(name, us, derived) for name, us, derived, _ in rows])
    if args.json:
        payload = [{"name": name, "us_per_call": us, "derived": derived,
                    "metrics": metrics}
                   for name, us, derived, metrics in rows]
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)


if __name__ == "__main__":
    main()
