"""Kernel-level benchmarks: SAC bit-plane matmul + kneaded integer GEMM.

Wall-times here are interpret-mode (CPU container) — meaningful only as
correctness-path cost; the TPU-relevant derived metrics are the HBM byte
ratios and the plane/tile skip fractions (what the roofline consumes).

``--quick`` shrinks shapes/bit sweeps to CI-smoke size; ``--json PATH``
additionally writes the rows as JSON (the per-PR perf artifact).
"""
from __future__ import annotations

import argparse
import json
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timed
from repro.core import knead, quantize
from repro.kernels.kneaded_gemm.ops import kneaded_gemm
from repro.kernels.kneaded_gemm.ref import pack_int4
from repro.kernels.sac_matmul.ops import sac_matmul_pallas
from repro.kernels.sac_matmul.ref import sac_matmul_ref


def run(quick: bool = False) -> List[Row]:
    rows: List[Row] = []
    key = jax.random.PRNGKey(0)
    m, k, n = (8, 256, 128) if quick else (8, 1024, 512)
    w = jax.random.normal(key, (k, n)) * 0.02
    a = jax.random.normal(jax.random.PRNGKey(1), (m, k))

    for bits in (4, 8) if quick else (4, 8, 16):
        kw = knead(w, bits=bits, ks=256, n_block=128)
        us, out = timed(lambda: sac_matmul_pallas(a, kw, bm=8), repeats=1)
        ref = sac_matmul_ref(a, kw)
        err = float(jnp.max(jnp.abs(out - ref)))
        occ = np.asarray(kw.occupancy)
        skip = 1.0 - occ.mean()
        ratio = kw.packed_bytes() / kw.dense_bf16_bytes()
        rows.append((
            f"kernel/sac_matmul_b{bits}", us,
            f"bytes_vs_bf16={ratio:.3f} plane_tile_skip={100*skip:.1f}% "
            f"max_err={err:.1e}"))

    qt8 = quantize(w, bits=8)
    us, out8 = timed(lambda: kneaded_gemm(a, qt8.q, qt8.scale.reshape(1, -1)),
                     repeats=1)
    rows.append(("kernel/kneaded_gemm_int8", us,
                 f"weight_bytes_vs_bf16=0.500 max_err="
                 f"{float(jnp.max(jnp.abs(out8 - a @ (qt8.q * qt8.scale)))):.1e}"))

    qt4 = quantize(w, bits=4)
    packed = pack_int4(qt4.q)
    us, out4 = timed(lambda: kneaded_gemm(a, packed, qt4.scale.reshape(1, -1),
                                          packed4=True), repeats=1)
    rows.append(("kernel/kneaded_gemm_int4", us,
                 f"weight_bytes_vs_bf16=0.250 max_err="
                 f"{float(jnp.max(jnp.abs(out4 - a @ (qt4.q * qt4.scale)))):.1e}"))

    # dense bf16 reference timing (XLA, not interpret — not comparable, but
    # shows the oracle cost scale)
    us, _ = timed(lambda: a.astype(jnp.bfloat16) @ w.astype(jnp.bfloat16))
    rows.append(("kernel/dense_bf16_xla_ref", us, "baseline_matmul"))
    return rows


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--quick", action="store_true",
                        help="CI smoke mode: small shapes, fewer bit widths")
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="also write rows as JSON to PATH")
    args = parser.parse_args()
    rows = run(quick=args.quick)
    from benchmarks.common import print_rows
    print_rows(rows)
    if args.json:
        payload = [{"name": name, "us_per_call": us, "derived": derived}
                   for name, us, derived in rows]
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)


if __name__ == "__main__":
    main()
