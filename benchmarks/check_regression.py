"""CI perf gate: compare a bench_kernels JSON against the committed baseline.

Gated metrics are the *deterministic* schedule/cycle quantities (committed
trained weights + fixed seeds), not wall clocks: ``executed_tile_dots`` and
``cycle_ratio`` are lower-is-better — a PR that makes the compacted schedule
dispatch more MXU passes, or worsens the kneaded cycle ratio, by more than
``--tolerance`` (default 10%) fails the build.  ``max_err`` is gated the
same way so kernel-accuracy regressions can't hide behind perf numbers.

Usage:
  python -m benchmarks.check_regression CURRENT.json \\
      [--baseline benchmarks/artifacts/bench_baseline.json] [--tolerance 0.10]

Regenerate the baseline (after an *intended* change, commit the diff):
  python -m benchmarks.bench_kernels --quick \\
      --json benchmarks/artifacts/bench_baseline.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict

BASELINE = pathlib.Path(__file__).resolve().parent / "artifacts" / \
    "bench_baseline.json"

# lower-is-better metrics the gate enforces (absolute counts and ratios —
# all reproducible bit-for-bit from committed weights).  shard_executed_max
# is the sharded sweep's critical-path load: the MXU passes the most-loaded
# device of the 4-shard partition executes — a PR that skews the N-shard
# balance (or inflates any shard's work list) by >tolerance fails even if
# the total stays flat.  shard_imbalance is the same skew as a ratio
# (max / mean shard work): the balanced-partition rows baseline it at
# ~1.0, so a packing change that un-balances an LPT row fails even when
# absolute work counts move with an intended schedule change.  The
# latency-tick metrics come from the
# serving_load_sweep's fixed Poisson trace on the virtual-launch clock:
# a scheduler change that makes requests wait more launches, or spends
# more launches on the same trace, fails the build.  failed_requests and
# retries come from serving_fault_sweep's deterministic fault plan: a
# fault-handling change that starts losing requests (baseline 0 — any
# loss fails) or needs more recovery attempts for the same injected
# faults fails too.  expert_imbalance is the moe_decode_sweep's static
# per-expert work-table skew (max / mean tile-dots across the fixed-seed
# skewed bank's experts): a kneading or bank-layout change that moves
# work between experts shifts it and fails, alongside the sweep's gated
# executed_tile_dots (runtime-masked routed work) and max_err (emulated
# expert-parallel vs all-local, baselined at exactly 0.0).
GATED = ("executed_tile_dots", "cycle_ratio", "max_err",
         "shard_executed_max", "shard_imbalance", "expert_imbalance",
         "p50_latency_ticks",
         "p95_latency_ticks", "total_ticks", "failed_requests", "retries")
# higher-is-better metrics: act_skip_frac is the activation-intersected
# skip fraction of the two-sided decode rows (docs/DESIGN.md §12) — a
# change that quietly stops intersecting the runtime activation occupancy
# (executed creeps back toward the weight-only count) drops the fraction
# and fails the build, symmetric to executed_tile_dots rising
GATED_HIGHER = ("act_skip_frac",)
# max_err floor: don't flag 1e-6-scale float noise as a "regression"
ABS_FLOOR = {"max_err": 1e-4}


def _by_name(rows) -> Dict[str, dict]:
    return {r["name"]: r.get("metrics", {}) for r in rows}


def compare(current: Dict[str, dict], baseline: Dict[str, dict],
            tolerance: float) -> list:
    failures = []
    for name, base_met in baseline.items():
        gated = {k: v for k, v in base_met.items()
                 if k in GATED or k in GATED_HIGHER}
        if not gated:
            continue
        if name not in current:
            failures.append(f"{name}: row missing from current bench output")
            continue
        cur_met = current[name]
        for key, base_val in gated.items():
            if key not in cur_met:
                failures.append(f"{name}.{key}: metric missing")
                continue
            cur_val = float(cur_met[key])
            if key in GATED_HIGHER:
                floor = float(base_val) * (1.0 - tolerance)
                if cur_val < floor:
                    failures.append(
                        f"{name}.{key}: {cur_val:.6g} fell below baseline "
                        f"{float(base_val):.6g} by more than "
                        f"{100 * tolerance:.0f}%")
                continue
            limit = float(base_val) * (1.0 + tolerance) + \
                ABS_FLOOR.get(key, 0.0)
            if cur_val > limit:
                failures.append(
                    f"{name}.{key}: {cur_val:.6g} exceeds baseline "
                    f"{float(base_val):.6g} by more than "
                    f"{100 * tolerance:.0f}%")
    return failures


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("current", help="bench_kernels --json output to check")
    parser.add_argument("--baseline", default=str(BASELINE))
    parser.add_argument("--tolerance", type=float, default=0.10)
    args = parser.parse_args(argv)

    with open(args.current) as f:
        current = _by_name(json.load(f))
    with open(args.baseline) as f:
        baseline = _by_name(json.load(f))

    failures = compare(current, baseline, args.tolerance)
    if failures:
        print("PERF REGRESSION vs committed baseline "
              f"({args.baseline}):", file=sys.stderr)
        for msg in failures:
            print(f"  FAIL {msg}", file=sys.stderr)
        return 1
    n = sum(1 for met in baseline.values()
            if any(k in GATED or k in GATED_HIGHER for k in met))
    print(f"perf gate OK: {n} baselined rows within "
          f"{100 * args.tolerance:.0f}% of {args.baseline}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
