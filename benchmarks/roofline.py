"""Roofline aggregation: dry-run artifacts -> §Roofline table.

For every (arch x shape x mesh [x quant/attn variant]) JSON produced by
``repro.launch.dryrun``, compute:

  compute_s    = HLO dot FLOPs / (chips * 197 TF/s)     [parsed, trip-aware]
  memory_s     = per-device working set / 819 GB/s      [memory_analysis]
  collective_s = collective bytes / (chips * 50 GB/s)   [parsed, trip-aware]

  MODEL_FLOPS  = 6*N*D (train) | 2*N_active*tokens (prefill/decode)
  useful_ratio = MODEL_FLOPS / HLO FLOPs   (remat/causal/dispatch waste)
  rf           = model-FLOPs time / max(term)  — the roofline fraction
                 (upper bound on MFU reachable with this compiled program)

Writes benchmarks/artifacts/roofline.md and prints a compact table.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

from repro.launch.mesh import PEAK_FLOPS_BF16

ART = pathlib.Path(__file__).resolve().parent / "artifacts"
DRY = ART / "dryrun"


def model_flops(rec: Dict) -> float:
    n_active = rec.get("active_params") or rec.get("params") or 0
    shape = rec["shape"]
    kind = rec.get("kind", "train")
    seq = {"train_4k": 4096, "prefill_32k": 32768, "decode_32k": 1,
           "long_500k": 1}[shape]
    batch = {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
             "long_500k": 1}[shape]
    tokens = seq * batch
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens


def analyze(rec: Dict) -> Optional[Dict]:
    if not rec.get("ok"):
        return None
    chips = rec["chips"]
    hlo = rec["hlo_per_device"]
    terms = rec["roofline_terms_s"]
    mf = model_flops(rec)
    hlo_global_flops = hlo["dot_flops"] * chips
    model_t = mf / (chips * PEAK_FLOPS_BF16)
    tmax = max(terms.values())
    return {
        "cell": f'{rec["arch"]}/{rec["shape"]}',
        "mesh": rec["mesh"],
        "variant": f'{rec.get("quant","bf16")}'
                   + (f'+{rec["attn_impl"]}' if rec.get("attn_impl") else ""),
        "compute_s": terms["compute_s"],
        "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "dominant": rec["dominant_term"].replace("_s", ""),
        "model_flops": mf,
        "useful_ratio": mf / hlo_global_flops if hlo_global_flops else 0.0,
        "rf": model_t / tmax if tmax else 0.0,
        "mem_gib": rec.get("hbm_bytes_per_device", 0) / 2**30,
        "arg_gib": rec["memory"]["argument_bytes"] / 2**30,
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "lever": _next_lever(rec),
    }


def _next_lever(rec) -> str:
    """One sentence: what would move the dominant term down (§Roofline)."""
    dom = rec["dominant_term"]
    kind = rec.get("kind", "train")
    par = rec.get("parallelism", "tp")
    quant = rec.get("quant", "bf16")
    if dom == "compute_s":
        return ("near compute roofline; next: raise useful ratio "
                "(attention/vocab share)")
    if dom == "memory_s":
        return ("kneaded int4 weights + int8 KV cache halve the byte term"
                if quant == "bf16" else "int8 KV cache next")
    if kind in ("decode", "prefill"):
        return ("weight gathers at dequantized width — explicit shard_map "
                "intN-gather matmul (future work); kneaded intN already "
                "cuts the gathered bytes" if quant != "bf16" else
                "kneaded int8/int4 weights cut the dominant weight-gather "
                "bytes 2-4x (§Perf C2)")
    if par == "dp":
        return ("grad reduce-scatter in bf16; ring context-parallel over "
                "pod to reclaim the 2x duplication")
    if rec.get("arch", "").find("moe") >= 0 or "arctic" in rec.get("arch", ""):
        return ("expert regathers are the floor at this scale; EP all-to-all "
                "token routing or more chips")
    return ("TP activation ARs: SP converts to RS/AG (memory win), fewer "
            "ARs/layer via qkv fusion; or dp profile if states fit")


def load_all() -> List[Dict]:
    out = []
    for f in sorted(DRY.glob("*.json")):
        rec = json.loads(f.read_text())
        a = analyze(rec)
        if a:
            out.append(a)
    return out


def render(rows: List[Dict]) -> str:
    hdr = ("| cell | mesh | variant | compute_s | memory_s | collective_s | "
           "dominant | useful=6ND/HLO | RF | arg GiB | temp GiB | "
           "next lever |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f'| {r["cell"]} | {r["mesh"]} | {r["variant"]} '
                 f'| {r["compute_s"]:.3e} | {r["memory_s"]:.3e} '
                 f'| {r["collective_s"]:.3e} | **{r["dominant"]}** '
                 f'| {r["useful_ratio"]:.3f} | {r["rf"]:.3f} '
                 f'| {r["arg_gib"]:.1f} | {r["temp_gib"]:.1f} '
                 f'| {r["lever"]} |\n')
    return hdr + body


def run():
    rows = load_all()
    md = render(rows)
    (ART / "roofline.md").write_text(md)
    out = []
    for r in rows:
        out.append((f'roofline/{r["cell"]}@{r["mesh"]}/{r["variant"]}', 0.0,
                    f'dom={r["dominant"]} RF={r["rf"]:.3f} '
                    f'useful={r["useful_ratio"]:.2f}'))
    return out


if __name__ == "__main__":
    for name, us, d in run():
        print(f"{name},{us:.1f},{d}")
