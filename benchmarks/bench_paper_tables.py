"""Paper-reproduction benchmarks: Table 1, Fig 2, Fig 8, Fig 9, Fig 10,
Fig 11, Table 2 — one function per artifact, all driven by real quantized
weights/activations of the paper's own CNN family (+ one modern LM for
context) through the cycle-accurate DaDN/PRA/Tetris cost model — plus the
``kneaded_e2e`` section, which runs the *real* kneaded execution path (SAC
matmuls on KneadedWeight, including the Pallas kernel) and reports per-layer
kneaded cycle ratios next to measured wall clock.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, cnn_layer_data, cnn_weights, timed
from repro.core import cost_model, quantize, sac_matmul, stats as wstats
from repro.core.kneading import knead_padded, kneading_ratio

CNNS = ("alexnet", "vgg16", "nin")

# Paper reference values for side-by-side reporting
PAPER_TABLE1 = {"alexnet": (0.093, 70.52), "vgg16": (0.156, 70.52),
                "nin": (0.193, 67.02)}
PAPER_FIG8 = {"tetris_fp16": 1.30, "tetris_int8": 1.50, "pra": 1.15}


def _layer_cost(w, act, bits, ks, mode):
    # per-tensor fixed point: the paper's hardware number format
    qw = quantize(w, bits=bits, axis=None)
    qa = quantize(jnp.abs(act[: min(4096, act.shape[0])]), bits=16, axis=None)
    return cost_model.model_layer(qw.q, qa.q, bits=bits, ks=ks, mode=mode)


def _model_speedups(name: str, ks: int = 16) -> Dict[str, float]:
    """MAC-weighted aggregate speedups for one CNN, fp16 + int8 modes."""
    weights, acts = cnn_layer_data(name)
    tot = {"dadn": 0.0, "pra": 0.0, "tetris16": 0.0,
           "dadn8": 0.0, "tetris8": 0.0}
    for lname, w in weights.items():
        act = acts[lname]
        c16 = _layer_cost(w, act, 16, ks, "fp16")
        c8 = _layer_cost(w, act, 8, ks, "int8")
        tot["dadn"] += c16.dadn
        tot["pra"] += c16.pra
        tot["tetris16"] += c16.tetris
        tot["dadn8"] += c8.dadn
        tot["tetris8"] += c8.tetris
    return {
        "pra": tot["dadn"] / tot["pra"],
        "tetris_fp16": tot["dadn"] / tot["tetris16"],
        "tetris_int8": tot["dadn8"] / tot["tetris8"],
    }


def bench_table1() -> List[Row]:
    """Table 1: zero-value % and zero-bit % of fixed-16 quantized weights."""
    rows: List[Row] = []
    aggregate = {}
    for name in CNNS:
        t0 = time.perf_counter()
        weights, _ = cnn_layer_data(name)
        per_layer = {ln: wstats.weight_bit_stats(w, bits=16)
                     for ln, w in weights.items()}
        agg = wstats.aggregate_stats(per_layer)
        us = (time.perf_counter() - t0) * 1e6
        ref = PAPER_TABLE1.get(name, ("-", "-"))
        rows.append((
            f"table1/{name}", us,
            f"zero_val%={100*agg.zero_value_frac:.3f} "
            f"zero_bit%={100*agg.zero_bit_frac:.2f} "
            f"(paper: {ref[0]}/{ref[1]})"))
        aggregate[name] = agg
    gm = np.exp(np.mean([np.log(100 * a.zero_bit_frac)
                         for a in aggregate.values()]))
    rows.append(("table1/geomean_zero_bit%", 0.0,
                 f"{gm:.2f} (paper: 68.88; gap = our 25-step CNNs are "
                 f"near-Gaussian, fully-trained ImageNet weights are "
                 f"heavy-tailed)"))
    # validation: a heavy-tailed (Student-t df=3) weight field — the
    # distribution shape of fully-trained conv layers — recovers the
    # paper's zero-bit regime under the same per-tensor fixed point.
    key = jax.random.PRNGKey(0)
    t3 = jax.random.t(key, 3.0, (512, 512))
    s_t3 = wstats.weight_bit_stats(t3, bits=16)
    rows.append(("table1/heavytail_t3_synthetic", 0.0,
                 f"zero_bit%={100*s_t3.zero_bit_frac:.2f} "
                 f"(paper trained-model regime: ~69)"))
    return rows


def bench_fig2() -> List[Row]:
    """Fig 2: essential-bit density per bit position (fixed-16 weights)."""
    rows: List[Row] = []
    dens = []
    for name in CNNS:
        weights, _ = cnn_layer_data(name)
        per_layer = {ln: wstats.weight_bit_stats(w, bits=16)
                     for ln, w in weights.items()}
        agg = wstats.aggregate_stats(per_layer)
        dens.append(agg.per_bit_density)
        head = " ".join(f"{d:.2f}" for d in agg.per_bit_density)
        rows.append((f"fig2/{name}", 0.0, f"density[b0..b14]=[{head}]"))
    mean = np.mean(dens, axis=0)
    rows.append(("fig2/mid_bit_mean_density", 0.0,
                 f"{np.mean(mean[2:10]):.3f} (paper: 0.50-0.60)"))
    rows.append(("fig2/top_bit_density", 0.0,
                 f"{mean[-1]:.4f} (paper cliff: <0.01 at sparse bits)"))
    return rows


def bench_fig8() -> List[Row]:
    """Fig 8: inference speedup vs DaDN (cycle model on real weights)."""
    rows: List[Row] = []
    alls: Dict[str, List[float]] = {"pra": [], "tetris_fp16": [],
                                    "tetris_int8": []}
    for name in CNNS:
        t0 = time.perf_counter()
        sp = _model_speedups(name, ks=16)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig8/{name}", us,
                     f"pra={sp['pra']:.2f}x tetris_fp16={sp['tetris_fp16']:.2f}x "
                     f"tetris_int8={sp['tetris_int8']:.2f}x"))
        for k in alls:
            alls[k].append(sp[k])
    for k, v in alls.items():
        gm = float(np.exp(np.mean(np.log(v))))
        rows.append((f"fig8/geomean_{k}", 0.0,
                     f"{gm:.3f}x (paper: {PAPER_FIG8[k]}x)"))
    return rows


def bench_fig9() -> List[Row]:
    """Fig 9: per-Conv-layer speedup of VGG-16, two KS configs."""
    rows: List[Row] = []
    weights, acts = cnn_layer_data("vgg16")
    for ks in (16, 32):
        parts = []
        for lname, w in weights.items():
            if not lname.startswith("conv"):
                continue
            c = _layer_cost(w, acts[lname], 16, ks, "fp16")
            parts.append(f"{lname}={c.dadn/c.tetris:.2f}")
        rows.append((f"fig9/vgg16_ks{ks}", 0.0, " ".join(parts)))
    return rows


def bench_fig10() -> List[Row]:
    """Fig 10: energy-delay product normalized to DaDN."""
    rows: List[Row] = []
    effs = {"pra": [], "tetris_fp16": [], "tetris_int8": []}
    for name in CNNS:
        sp = _model_speedups(name, ks=16)
        # EDP ∝ P * T^2; improvement = EDP_dadn / EDP_x = speedup^2 / P_ratio
        e = {
            "pra": sp["pra"] ** 2 / cost_model.POWER_RATIO["pra"],
            "tetris_fp16": sp["tetris_fp16"] ** 2
            / cost_model.POWER_RATIO["tetris"],
            "tetris_int8": sp["tetris_int8"] ** 2
            / cost_model.POWER_RATIO["tetris"],
        }
        rows.append((f"fig10/{name}", 0.0,
                     f"EDP_impr: pra={e['pra']:.2f}x "
                     f"tetris_fp16={e['tetris_fp16']:.2f}x "
                     f"tetris_int8={e['tetris_int8']:.2f}x"))
        for k in effs:
            effs[k].append(e[k])
    gm = {k: float(np.exp(np.mean(np.log(v)))) for k, v in effs.items()}
    rows.append(("fig10/geomean", 0.0,
                 f"tetris_fp16={gm['tetris_fp16']:.2f}x (paper 1.24x) "
                 f"tetris_int8={gm['tetris_int8']:.2f}x (paper 1.46x) "
                 f"pra={gm['pra']:.2f}x (paper 0.35x=1/2.87)"))
    return rows


def bench_fig11() -> List[Row]:
    """Fig 11: T_ks / T_base for KS in {10,16,24,32}, fp16 + int8."""
    rows: List[Row] = []
    for name in CNNS:
        weights, _ = cnn_layer_data(name)
        big = max(weights.items(), key=lambda kv: kv[1].size)[1]
        for bits, mode in ((16, "fp16"), (8, "int8")):
            qw = quantize(big, bits=bits, axis=None)
            vals = []
            for ks in (10, 16, 24, 32):
                k = (qw.q.shape[0] // ks) * ks
                r = float(kneading_ratio(qw.q[:k], bits, ks))
                vals.append(f"ks{ks}={100*r:.1f}%")
            rows.append((f"fig11/{name}_{mode}", 0.0, " ".join(vals)))
    rows.append(("fig11/paper_ref", 0.0,
                 "paper alexnet fp16: ks10=75.1% ks32=64.2%; int8 49.4-48.8% "
                 "(int8 halves cycles at equal ratio)"))
    return rows


def bench_table2() -> List[Row]:
    """Table 2: area model.  We cannot synthesize (no EDA tools); the model
    reproduces the paper's component breakdown and scales splitter area with
    KS decode width (log2 KS) and segment adders with bit width."""
    # paper per-PE areas (mm^2, TSMC 65nm)
    base = {"io_rams": 3.828, "throttle": 0.957, "splitter": 0.544,
            "act_fn": 0.143, "seg_adders": 0.129, "adder_tree": 0.008}
    dadn_total = 79.36

    def pe_area(ks: int, bits: int) -> float:
        s = dict(base)
        s["splitter"] = base["splitter"] * (np.log2(ks) / 4.0)   # p-width
        s["seg_adders"] = base["seg_adders"] * (bits / 16.0)
        return sum(s.values())

    rows: List[Row] = []
    a16 = 16 * pe_area(16, 16)
    rows.append(("table2/tetris_fp16_total_mm2", 0.0,
                 f"{a16:.2f} (paper: 89.76; overhead vs DaDN "
                 f"{a16/dadn_total:.3f}x, paper 1.131x)"))
    for ks in (8, 16, 32):
        rows.append((f"table2/area_ks{ks}", 0.0,
                     f"{16*pe_area(ks,16):.2f} mm2"))
    frac = {k: v / sum(base.values()) for k, v in base.items()}
    rows.append(("table2/breakdown", 0.0,
                 " ".join(f"{k}={100*v:.1f}%" for k, v in frac.items())))
    return rows


def bench_kneaded_e2e() -> List[Row]:
    """The real execution path behind Figs 8/10/11: per-layer kneaded cycle
    ratios (the model) side by side with measured wall clock of the SAC
    matmul on the layer's real activations (the execution), for AlexNet.

    Wall clocks are CPU numbers — the "int" path is the XLA integer-code
    matmul, the "pallas" row runs the schedule-compacted kernel in interpret
    mode (a correctness-path cost, not a TPU projection).
    """
    from repro.inference.cnn_engine import CNNServingConfig, CNNServingEngine
    from repro.models import cnn

    rows: List[Row] = []
    name = "alexnet"
    cfg = cnn.CNN_ZOO[name]
    params = cnn_weights(name)
    weights, acts = cnn_layer_data(name)

    # per-layer: cycle model ratio (hardware ks=16) vs measured wall clock
    for lname, w in weights.items():
        act = jnp.asarray(acts[lname][:1024])
        w = jnp.asarray(w)
        q = quantize(w, bits=8, axis=None).q
        k16 = (q.shape[0] // 16) * 16
        ratio = float(kneading_ratio(q[:k16], 8, 16))
        kw = knead_padded(w, bits=8, ks=256)
        us_float, _ = timed(jax.jit(lambda a, w=w: a @ w), act)
        us_sac, _ = timed(jax.jit(lambda a, kw=kw: sac_matmul(a, kw,
                                                              impl="int")),
                          act)
        rows.append((
            f"kneaded_e2e/{name}/{lname}", us_sac,
            f"cycle_ratio={100*ratio:.1f}% wall_float={us_float:.0f}us "
            f"wall_sac_int={us_sac:.0f}us shape={tuple(w.shape)}"))

    # end-to-end: the serving engine, float vs fully-kneaded forward
    x = jax.random.normal(jax.random.PRNGKey(5),
                          (4, cfg.image_size, cfg.image_size, 3))
    eng_f = CNNServingEngine(cfg, params, CNNServingConfig(impl="float"))
    eng_i = CNNServingEngine(cfg, params, CNNServingConfig(impl="int"))
    us_f, ref = timed(eng_f.logits, x)
    us_i, out = timed(eng_i.logits, x)
    agree = float(jnp.mean((jnp.argmax(out, -1) == jnp.argmax(ref, -1))
                           .astype(jnp.float32)))
    rows.append((f"kneaded_e2e/{name}/forward_int8", us_i,
                 f"wall_float={us_f:.0f}us wall_kneaded={us_i:.0f}us "
                 f"top1_agreement={100*agree:.0f}% "
                 f"serving_bytes_ratio="
                 f"{eng_i.serving_bytes() / max(1, eng_f.serving_bytes()):.3f}"))

    # the Pallas kernel end to end (interpret mode): small config, one pass
    small = dataclasses.replace(cfg, image_size=16)
    sparams = cnn.init(jax.random.PRNGKey(0), small)
    xs = jax.random.normal(jax.random.PRNGKey(6), (2, 16, 16, 3))
    eng_g = CNNServingEngine(small, sparams,
                             CNNServingConfig(impl="pallas", jit=False))
    eng_p = CNNServingEngine(small, sparams,
                             CNNServingConfig(impl="planes", jit=False))
    us_g, lg = timed(eng_g.logits, xs, repeats=1)
    _, lp = timed(eng_p.logits, xs, repeats=1)
    exact = bool(np.array_equal(np.asarray(lg), np.asarray(lp)))
    rows.append((f"kneaded_e2e/{name}16/forward_pallas", us_g,
                 f"interpret_wall={us_g/1e6:.2f}s "
                 f"bit_exact_vs_planes={exact}"))
    return rows


def run() -> List[Row]:
    rows: List[Row] = []
    for fn in (bench_table1, bench_fig2, bench_fig8, bench_fig9,
               bench_fig10, bench_fig11, bench_table2, bench_kneaded_e2e):
        rows.extend(fn())
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
