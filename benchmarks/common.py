"""Shared benchmark utilities: trained CNN weights (cached), timing, CSV."""
from __future__ import annotations

import pathlib
import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

ART = pathlib.Path(__file__).resolve().parent / "artifacts"
ART.mkdir(exist_ok=True)

Row = Tuple[str, float, str]     # (name, us_per_call, derived)


def timed(fn: Callable, *args, repeats: int = 3) -> Tuple[float, object]:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
        jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return us, out


def cnn_weights(name: str, trained: bool = True) -> Dict:
    """Lightly-trained CNN weights, cached to disk (paper measures trained
    Caffe models — training sharpens the weight distribution toward zero)."""
    from repro.models import cnn
    cache = ART / f"cnn_{name}{'_trained' if trained else ''}.npz"
    cfg = cnn.CNN_ZOO[name]
    if cache.exists():
        data = np.load(cache)
        params = cnn.init(jax.random.PRNGKey(0), cfg)
        flat, treedef = jax.tree_util.tree_flatten(params)
        flat = [jnp.asarray(data[f"leaf_{i}"]) for i in range(len(flat))]
        return jax.tree_util.tree_unflatten(treedef, flat)
    if trained:
        params = cnn.train_briefly(jax.random.PRNGKey(0), cfg, steps=25,
                                   batch=16)
    else:
        params = cnn.init(jax.random.PRNGKey(0), cfg)
    flat, _ = jax.tree_util.tree_flatten(params)
    np.savez(cache, **{f"leaf_{i}": np.asarray(x)
                       for i, x in enumerate(flat)})
    return params


def cnn_layer_data(name: str):
    """(weight matrices, activation samples) per layer for the cost model."""
    from repro.models import cnn
    cfg = cnn.CNN_ZOO[name]
    params = cnn_weights(name)
    x = jax.random.normal(jax.random.PRNGKey(3),
                          (4, cfg.image_size, cfg.image_size, 3))
    _, acts = cnn.apply(params, x, cfg, collect_activations=True)
    return cnn.weight_matrices(params), acts


def print_rows(rows: List[Row]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
