"""Mesh-sharded kneaded LM serving (docs/DESIGN.md §8).

Covers the stacked schedule partition end to end: ``shard_stacked_schedule``
structure (per-layer slab equality against the single-layer sharder,
N-tiles that don't divide the shard count, all-empty shards), bit-exact
parity of the scan-sliced sharded matmul against the unsharded stacked
kernel, the engine validation surface, and the acceptance criterion — a
ServingEngine with ``shards ∈ {2, 4}`` on forced host devices producing
smollm-360m prefill logits and 32-token greedy generations bit-identical
to the unsharded single-device engine.

Oracle note (same as tests/test_sharded.py): forcing host devices perturbs
XLA CPU threading for large dense matmuls, so multi-device runs compare
against a clean 1-device subprocess.  At smoke-LM dims the dense ops
between the kneaded matmuls are small enough to be threading-stable, which
is what lets the cross-process comparison stay *bitwise* rather than
allclose (verified empirically; a future arch whose smoke dims drift
should fall back to comparing generations plus tight-tolerance logits).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kneading import knead_padded, knead_stacked
from repro.core.sac import sac_matmul
from repro.core.schedule import (ShardedStackedKneadedWeight, shard_schedule,
                                 shard_stacked_schedule)
from repro.inference.engine import ServingConfig, ServingEngine, knead_params
from repro.models.lm import LanguageModel


def _stacked_w(seed, layers, k, n, sparsity=0.0):
    kk = jax.random.split(jax.random.PRNGKey(seed), 2)
    w = jax.random.normal(kk[0], (layers, k, n)) * 0.05
    if sparsity > 0:
        keep = jax.random.uniform(kk[1], w.shape) >= sparsity
        w = w * keep
    return w


def _scan_matmul(a, stacked_kw):
    """Run a through every layer of a stacked (possibly sharded) kneaded
    weight via lax.scan — the model's slicing pattern."""
    def body(carry, kw_l):
        return carry, sac_matmul(a, kw_l, impl="pallas")
    _, outs = jax.lax.scan(body, 0, stacked_kw)
    return outs                                      # [L, M, N]


# ------------------------------------------------------------- structure

def test_shard_stacked_matches_per_layer_shard():
    """Layer l of the stacked sharded weight holds exactly the slabs
    shard_schedule(knead_padded(w[l])) builds, up to the work-dim padding
    to the cross-layer max; per-layer work rows partition each layer's
    unsharded total."""
    w = _stacked_w(0, 3, 300, 384, sparsity=0.6)
    stacked = knead_stacked(w, bits=8)
    ssk = shard_stacked_schedule(stacked, 2)
    assert isinstance(ssk, ShardedStackedKneadedWeight)
    assert ssk.num_layers == 3 and ssk.num_shards == 2
    for layer in range(3):
        solo = shard_schedule(knead_padded(w[layer], bits=8), 2)
        np.testing.assert_array_equal(np.asarray(ssk.planes[layer]),
                                      np.asarray(solo.planes))
        np.testing.assert_array_equal(np.asarray(ssk.signs[layer]),
                                      np.asarray(solo.signs))
        np.testing.assert_array_equal(np.asarray(ssk.scale[layer]),
                                      np.asarray(solo.scale))
        np.testing.assert_array_equal(np.asarray(ssk.counts[layer]),
                                      np.asarray(solo.counts))
        width = solo.num_work      # stacked pads work to the cross-layer max
        np.testing.assert_array_equal(
            np.asarray(ssk.plane_ids[layer][..., :width]),
            np.asarray(solo.plane_ids))
        np.testing.assert_array_equal(
            np.asarray(ssk.ktile_ids[layer][..., :width]),
            np.asarray(solo.ktile_ids))
        assert ssk.layer_shard_work[layer] == solo.shard_work
        assert sum(ssk.layer_shard_work[layer]) == \
            knead_padded(w[layer], bits=8).schedule.total_work
    assert ssk.shard_work == tuple(
        sum(row[s] for row in ssk.layer_shard_work) for s in range(2))
    assert ssk.total_work == stacked.schedule.total_work


def test_shard_stacked_indivisible_tiles():
    """3 N-tiles over 2 shards: one all-empty padding tile appended on every
    layer; parity stays bit-exact after the logical-N slice."""
    w = _stacked_w(1, 2, 512, 384)               # 3 N-tiles
    stacked = knead_stacked(w, bits=8)
    ssk = shard_stacked_schedule(stacked, 2)
    assert ssk.tiles_per_shard == 2 and ssk.n == 512   # 3 -> 4 tiles
    assert ssk.logical_n == 384
    assert ssk.total_work == stacked.schedule.total_work
    a = jax.random.normal(jax.random.PRNGKey(2), (8, 512))
    out = _scan_matmul(a, ssk)
    ref = _scan_matmul(a, stacked)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_shard_stacked_empty_shard():
    """Layers whose upper output columns are all zero put zero work on the
    second shard of every layer; outputs stay bit-exact and the per-layer
    imbalance report pins the skew."""
    w = _stacked_w(3, 2, 512, 512).at[:, :, 256:].set(0.0)
    stacked = knead_stacked(w, bits=8)
    ssk = shard_stacked_schedule(stacked, 2)
    for layer in range(2):
        assert ssk.layer_shard_work[layer][1] == 0
        assert ssk.layer_shard_work[layer][0] > 0
        assert ssk.layer_imbalance(layer)["imbalance"] == pytest.approx(2.0)
    assert ssk.imbalance()["max_layer_imbalance"] == pytest.approx(2.0)
    a = jax.random.normal(jax.random.PRNGKey(4), (8, 512))
    out = _scan_matmul(a, ssk)
    np.testing.assert_array_equal(
        np.asarray(out[:, :, 256:]), np.zeros((2, 8, 256), np.float32))
    ref = _scan_matmul(a, stacked)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# --------------------------------------------------------- serial parity

@pytest.mark.parametrize("shards", [1, 2, 4])
def test_scan_sliced_sharded_matmul_bit_exact(shards):
    """The serial shard walk of every scan-sliced layer is bit-exact
    against the unsharded stacked kernel — prefill (M=8) and decode-GEMV
    (M=1) regimes both."""
    w = _stacked_w(5, 3, 512, 512, sparsity=0.7)
    stacked = knead_stacked(w, bits=8)
    ssk = shard_stacked_schedule(stacked, shards)
    for m in (1, 8):
        a = jax.random.normal(jax.random.PRNGKey(6 + m), (m, 512))
        out = _scan_matmul(a, ssk)
        ref = _scan_matmul(a, stacked)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_sharded_weight_requires_pallas_impl():
    w = _stacked_w(7, 2, 512, 256)
    ssk = shard_stacked_schedule(knead_stacked(w, bits=8), 2)

    def run(carry, kw_l):
        return carry, sac_matmul(jnp.ones((1, 512)), kw_l, impl="planes")

    with pytest.raises(ValueError, match="Pallas kernel only"):
        jax.lax.scan(run, 0, ssk)


def test_stacked_sharded_must_be_sliced():
    """The full [L, S, ...] weight cannot hit the matmul un-sliced."""
    w = _stacked_w(8, 2, 512, 256)
    ssk = shard_stacked_schedule(knead_stacked(w, bits=8), 2)
    with pytest.raises(ValueError, match="un-sliced"):
        sac_matmul(jnp.ones((1, 512)), ssk, impl="pallas")


def test_shard_stacked_rejects_unstacked():
    kw = knead_padded(jax.random.normal(jax.random.PRNGKey(9), (512, 256)))
    with pytest.raises(ValueError, match="stacked"):
        shard_stacked_schedule(kw, 2)


def test_shard_stacked_balanced_per_layer_repartition():
    """Balanced stacked sharding repartitions each layer independently:
    two layers dense in *opposite* column halves both reach imbalance 1.0,
    each through its own row of the tile->shard permutation table, and the
    scan-sliced matmul stays bit-exact against the unsharded stack."""
    w = _stacked_w(10, 2, 512, 512)
    w = w.at[0, :, 256:].set(0.0).at[1, :, :256].set(0.0)
    stacked = knead_stacked(w, bits=8)
    cont = shard_stacked_schedule(stacked, 2)
    assert cont.imbalance()["max_layer_imbalance"] == pytest.approx(2.0)
    bal = shard_stacked_schedule(stacked, 2, partition="balanced")
    assert bal.tile_slot.shape == (2, 4)
    for layer in range(2):
        row = np.asarray(bal.tile_slot[layer])
        assert sorted(row.tolist()) == [0, 1, 2, 3]        # bijection
        assert bal.layer_imbalance(layer)["imbalance"] == pytest.approx(1.0)
    assert bal.imbalance()["max_layer_imbalance"] == pytest.approx(1.0)
    # the layers genuinely got different permutations
    assert not np.array_equal(np.asarray(bal.tile_slot[0]),
                              np.asarray(bal.tile_slot[1]))
    a = jax.random.normal(jax.random.PRNGKey(11), (8, 512))
    np.testing.assert_array_equal(np.asarray(_scan_matmul(a, bal)),
                                  np.asarray(_scan_matmul(a, stacked)))


# ------------------------------------------------------ engine validation

def test_engine_sharded_requires_pallas():
    from repro.configs.registry import get_config
    cfg = get_config("smollm-360m", smoke=True)
    params = LanguageModel(cfg).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="single-device only"):
        ServingEngine(cfg, params,
                      ServingConfig(impl="int", shards=2, knead_min_dim=8))


def test_knead_params_shards_every_kneadable_leaf():
    from repro.configs.registry import get_config
    cfg = get_config("smollm-360m", smoke=True)
    params = LanguageModel(cfg).init(jax.random.PRNGKey(0))
    kparams = knead_params(params, bits=8, min_dim=8, kneaded=True, shards=2)
    layers = kparams["layers"]
    for block, names in (("attn", ("wq", "wk", "wv", "wo")),
                         ("mlp", ("wi_gate", "wi_up", "wo"))):
        for name in names:
            leaf = layers[block][name]
            assert isinstance(leaf, ShardedStackedKneadedWeight), (block, name)
            assert leaf.num_layers == cfg.num_layers
            assert leaf.num_shards == 2
            assert leaf.planes.shape[:2] == (cfg.num_layers, 2)


# ------------------------------------------- multi-device acceptance test

_ENGINE_RUN = textwrap.dedent("""
    import json, sys
    import jax, numpy as np
    from repro.configs.registry import get_config
    from repro.inference.engine import ServingConfig, ServingEngine
    from repro.models.lm import LanguageModel

    shards = int(sys.argv[2])
    partition = sys.argv[3]
    act_skip = len(sys.argv) > 4 and sys.argv[4] == "1"
    cfg = get_config("smollm-360m", smoke=True)
    params = LanguageModel(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    eng = ServingEngine(cfg, params, ServingConfig(
        max_len=48, impl="pallas", knead_min_dim=8, shards=shards,
        shard_partition=partition, activation_skip=act_skip))
    with eng._mesh_ctx():
        logits, _ = eng._prefill(eng.params, {"tokens": toks})
    gen = eng.generate({"tokens": toks}, 32)
    np.save(sys.argv[1] + "_logits.npy",
            np.asarray(logits.astype(np.float32)))
    np.save(sys.argv[1] + "_gen.npy", np.asarray(gen))
    meta = {"devices": jax.device_count()}
    if shards > 1:
        leaf = eng.params["layers"]["attn"]["wq"]
        rep = leaf.imbalance()
        meta["wq_shard_work"] = rep["shard_work"]
        meta["wq_max_layer_imbalance"] = rep["max_layer_imbalance"]
    print(json.dumps(meta))
""")


def _run(code, out_prefix, shards, extra_env, partition="contiguous",
         activation_skip=False):
    env = {"PYTHONPATH": "src", "PATH": os.environ.get("PATH",
                                                       "/usr/bin:/bin")}
    env.update(extra_env)
    res = subprocess.run([sys.executable, "-c", code, out_prefix,
                          str(shards), partition,
                          "1" if activation_skip else "0"],
                         capture_output=True, text=True, env=env,
                         cwd=".", timeout=1200)
    assert res.returncode == 0, res.stderr[-2000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


@pytest.fixture(scope="module")
def oracle_run(tmp_path_factory):
    """The clean single-device unsharded engine run, computed ONCE for the
    whole shards parametrization (the oracle command is identical for
    every shard count)."""
    prefix = str(tmp_path_factory.mktemp("lm_oracle") / "oracle")
    meta = _run(_ENGINE_RUN, prefix, 0, {"JAX_PLATFORMS": "cpu"})
    return prefix, meta


@pytest.mark.parametrize("partition", ["contiguous", "balanced"])
@pytest.mark.parametrize("shards", [2, 4])
def test_sharded_lm_engine_bit_exact_vs_single_device_oracle(
        shards, partition, tmp_path, oracle_run):
    """ACCEPTANCE: ServingEngine with every kneaded projection's schedule
    sharded over forced host devices (shard_map-launched SAC kernels inside
    the layer scans) produces smollm-360m prefill logits AND 32-token
    greedy generations bit-identical to the unsharded engine on a clean
    single device — under both tile->shard partitionings.  Smoke dims pad
    every projection to a single N-tile, so "balanced" degenerates to the
    same placement (one tile can't be split); the point of the balanced leg
    is that the permutation-gather epilogue is exercised end to end through
    the full engine and changes nothing."""
    oracle_prefix, oracle_meta = oracle_run
    n_force = int(os.environ.get("REPRO_SHARD_TEST_DEVICES", "4"))
    sharded_meta = _run(
        _ENGINE_RUN, str(tmp_path / "sharded"), shards,
        {"XLA_FLAGS": f"--xla_force_host_platform_device_count={n_force}",
         "JAX_PLATFORMS": "cpu"}, partition=partition)
    assert sharded_meta["devices"] == n_force
    assert oracle_meta["devices"] == 1
    np.testing.assert_array_equal(
        np.load(tmp_path / "sharded_logits.npy"),
        np.load(oracle_prefix + "_logits.npy"))
    np.testing.assert_array_equal(
        np.load(tmp_path / "sharded_gen.npy"),
        np.load(oracle_prefix + "_gen.npy"))
    # static load accounting survived the trip through the engine: smoke
    # dims pad every projection to one N-tile, so all real work sits on
    # shard 0 and the report must say exactly that
    assert sharded_meta["wq_shard_work"][0] > 0
    assert all(wk == 0 for wk in sharded_meta["wq_shard_work"][1:])
    assert sharded_meta["wq_max_layer_imbalance"] == pytest.approx(
        float(shards))


@pytest.mark.parametrize("shards,partition",
                         [(2, "contiguous"), (4, "balanced")])
def test_sharded_lm_engine_activation_skip_bit_exact(
        shards, partition, tmp_path, oracle_run):
    """Activation-skip x sharding (docs/DESIGN.md §12): the sharded engine
    with ``activation_skip=True`` must stay bit-identical to the clean
    single-device *skip-off* oracle — presence is computed once from the
    full decode row (shard-invariant under N-sharding), the survival mask
    is sliced per shard, and surviving tile-dots keep the k-major order, so
    neither the mask intersection nor the balanced permutation epilogue may
    move a single bit of the prefill logits or the 32-token generation."""
    oracle_prefix, oracle_meta = oracle_run
    n_force = int(os.environ.get("REPRO_SHARD_TEST_DEVICES", "4"))
    sharded_meta = _run(
        _ENGINE_RUN, str(tmp_path / "skip"), shards,
        {"XLA_FLAGS": f"--xla_force_host_platform_device_count={n_force}",
         "JAX_PLATFORMS": "cpu"}, partition=partition, activation_skip=True)
    assert sharded_meta["devices"] == n_force
    assert oracle_meta["devices"] == 1
    np.testing.assert_array_equal(
        np.load(tmp_path / "skip_logits.npy"),
        np.load(oracle_prefix + "_logits.npy"))
    np.testing.assert_array_equal(
        np.load(tmp_path / "skip_gen.npy"),
        np.load(oracle_prefix + "_gen.npy"))
