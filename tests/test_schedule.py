"""Compacted-schedule correctness: structure, parity, and extremes.

The KneadedSchedule is *the* execution plan of the Pallas kernel — these
tests pin (a) its structural invariants against the occupancy map it was
built from, (b) bit-exact output parity of the schedule-driven kernel vs the
dense planes oracle vs the item-by-item ``replay_schedule`` spec across
random shapes and sparsities, (c) the all-empty / all-dense occupancy
extremes the grid must survive (num_work floor of 1; zero dispatched work),
and (d) the balanced shard partitioner's invariants (docs/DESIGN.md §11):
for any occupancy, ``partition="balanced"`` never loads its worst shard
more than contiguous does, its ``tile_slot`` is a bijection covering every
N-tile, and the permuted-then-gathered execution stays bit-exact against
the unsharded kernel across the sparsity extremes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import activation_occupancy as actocc
from repro.core import knead, sac_matmul
from repro.core.bitplanes import pack_presence, popcount, unpack_presence
from repro.core.kneading import knead_padded
from repro.core.schedule import build_schedule, replay_schedule, shard_schedule
from repro.kernels.sac_matmul.ops import (sac_matmul_pallas,
                                          sac_matmul_pallas_sharded)

settings.register_profile("ci2", deadline=None, max_examples=15)
settings.load_profile("ci2")


def _sparse_w(seed, k, n, sparsity):
    kk = jax.random.split(jax.random.PRNGKey(seed), 2)
    w = jax.random.normal(kk[0], (k, n)) * 0.05
    if sparsity > 0:
        keep = jax.random.uniform(kk[1], (k, n)) >= sparsity
        w = w * keep
    return w


# ----------------------------------------------------------- structure
def test_schedule_structure_matches_occupancy():
    """Schedule items enumerate exactly the nonzero occupancy entries,
    k-major per N-tile, padded by repeating the last real item."""
    rng = np.random.default_rng(0)
    occ = (rng.random((7, 5, 3)) < 0.3).astype(np.int32)
    sched = build_schedule(occ)
    assert sched.total_work == int(occ.sum())
    assert sched.nk == 5 and sched.n_tiles == 3
    assert sched.num_work == max(1, int(occ.sum(axis=(0, 1)).max()))
    counts = np.asarray(sched.counts)
    pid, kid = np.asarray(sched.plane_ids), np.asarray(sched.ktile_ids)
    for j in range(3):
        c = int(counts[j])
        assert c == int(occ[:, :, j].sum())
        items = list(zip(kid[j, :c].tolist(), pid[j, :c].tolist()))
        # exactly the nonzero (k_tile, plane) pairs, sorted k-major
        expect = sorted((k, b) for b in range(7) for k in range(5)
                        if occ[b, k, j])
        assert items == expect
        if c:  # padding repeats the last real item (no new blocks fetched)
            assert (pid[j, c:] == pid[j, c - 1]).all()
            assert (kid[j, c:] == kid[j, c - 1]).all()
        else:
            assert (pid[j] == 0).all() and (kid[j] == 0).all()


def test_pack_presence_roundtrip():
    rng = np.random.default_rng(1)
    occ = (rng.random((7, 37, 4)) < 0.5).astype(np.int32)   # NK not | 32
    packed = pack_presence(jnp.asarray(occ))
    assert packed.dtype == jnp.uint32 and packed.shape == (7, 2, 4)
    assert np.array_equal(np.asarray(unpack_presence(packed, 37)), occ)


# ------------------------------------------------- parity (property-based)
@given(seed=st.integers(0, 10),
       shape=st.sampled_from([(8, 256, 128), (8, 512, 128), (4, 512, 256)]),
       bits=st.sampled_from([4, 8]),
       sparsity=st.sampled_from([0.0, 0.7, 0.95]))
def test_schedule_parity_bit_exact(seed, shape, bits, sparsity):
    """Compacted kernel == dense planes oracle == schedule replay, bitwise,
    across shapes and occupancy densities."""
    m, k, n = shape
    w = _sparse_w(seed, k, n, sparsity)
    a = jax.random.normal(jax.random.PRNGKey(seed + 99), (m, k))
    kw = knead(w, bits=bits, ks=256, n_block=128)
    out_planes = sac_matmul(a, kw, impl="planes")
    out_pallas = sac_matmul(a, kw, impl="pallas")
    np.testing.assert_array_equal(np.asarray(out_pallas),
                                  np.asarray(out_planes))
    out_replay = replay_schedule(a, kw)[:, :kw.logical_n]
    np.testing.assert_array_equal(np.asarray(out_replay),
                                  np.asarray(out_planes))


def test_schedule_parity_sparse_smoke():
    """Non-hypothesis fallback of the parity property: one sparse case runs
    in every environment (the @given sweep broadens it when hypothesis is
    installed)."""
    # element sparsity alone rarely empties a whole 256x128 tile — zero the
    # second K block outright so the schedule provably compacts
    w = _sparse_w(5, 512, 128, sparsity=0.9).at[256:].set(0.0)
    a = jax.random.normal(jax.random.PRNGKey(6), (8, 512))
    kw = knead(w, bits=8, ks=256, n_block=128)
    assert kw.schedule.total_work < kw.schedule.dense_work(kw.bits)
    out_planes = sac_matmul(a, kw, impl="planes")
    out_pallas = sac_matmul(a, kw, impl="pallas")
    np.testing.assert_array_equal(np.asarray(out_pallas),
                                  np.asarray(out_planes))
    out_replay = replay_schedule(a, kw)[:, :kw.logical_n]
    np.testing.assert_array_equal(np.asarray(out_replay),
                                  np.asarray(out_planes))


# --------------------------------------------------------------- extremes
def test_schedule_all_empty():
    """An all-zero weight schedules ZERO work; the kernel must still write
    its (all-zero) output through the num_work >= 1 grid floor."""
    w = jnp.zeros((512, 128))
    a = jax.random.normal(jax.random.PRNGKey(0), (8, 512))
    kw = knead(w, bits=8, ks=256, n_block=128)
    assert kw.schedule.total_work == 0
    assert kw.schedule.num_work == 1            # grid floor, idles through
    assert int(np.asarray(kw.schedule.counts).sum()) == 0
    out = sac_matmul_pallas(a, kw, bm=8)
    assert out.shape == (8, 128)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((8, 128), np.float32))


def test_schedule_all_dense():
    """Fully-occupied weights schedule the dense work count — compaction
    never *adds* work, and parity still holds bitwise."""
    kk = jax.random.split(jax.random.PRNGKey(7), 2)
    # |w| in [0.5, 1]: every magnitude bit appears in every 256x128 tile
    w = (jnp.sign(jax.random.normal(kk[0], (512, 128)))
         * (0.5 + 0.5 * jax.random.uniform(kk[1], (512, 128))))
    a = jax.random.normal(jax.random.PRNGKey(8), (8, 512))
    kw = knead(w, bits=8, ks=256, n_block=128)
    assert kw.schedule.total_work == kw.schedule.dense_work(kw.bits)
    assert kw.schedule.num_work == (kw.bits - 1) * (kw.k // kw.ks)
    out_planes = sac_matmul(a, kw, impl="planes")
    out_pallas = sac_matmul(a, kw, impl="pallas")
    np.testing.assert_array_equal(np.asarray(out_pallas),
                                  np.asarray(out_planes))


# ------------------------------- balanced partitioner (docs/DESIGN.md §11)
#
# Load properties run on crafted occupancy maps (``with_occupancy`` installs
# them over an all-zero weight — shard accounting reads counts only, so no
# execution is needed); bit-exactness properties run real sparse weights
# through the gathered sharded kernel against the unsharded one.

def _occ_kw(occ):
    """A minimal kneaded weight carrying a crafted occupancy map."""
    nb, nk, nn = occ.shape
    w = jnp.zeros((nk * 256, nn * 128))
    return knead(w, bits=nb + 1, ks=256, n_block=128).with_occupancy(
        jnp.asarray(occ))


def _check_balanced_properties(occ, shards):
    kw = _occ_kw(occ)
    cont = shard_schedule(kw, shards)
    bal = shard_schedule(kw, shards, partition="balanced")
    total = shards * bal.tiles_per_shard
    # balanced never loads its worst shard more than contiguous
    assert max(bal.shard_work) <= max(cont.shard_work)
    # work is conserved: both partitions carry every occupancy nonzero
    assert sum(bal.shard_work) == sum(cont.shard_work) == int(occ.sum())
    # tile_slot is a bijection covering all (real + padding) N-tiles
    slot = np.asarray(bal.tile_slot)
    assert sorted(slot.tolist()) == list(range(total))
    # contiguous mode records the identity permutation
    np.testing.assert_array_equal(np.asarray(cont.tile_slot),
                                  np.arange(total))
    # the packed counts really sit where tile_slot says they do
    packed = np.asarray(bal.counts).reshape(-1)
    orig = np.asarray(kw.schedule.counts)
    for j in range(orig.size):
        assert packed[slot[j]] == orig[j]
    # both partitions verify clean against their shard-time checksums
    assert not bal.verify() and not cont.verify()


@given(seed=st.integers(0, 1000),
       shards=st.sampled_from([2, 3, 4]),
       nn=st.integers(2, 12),
       density=st.sampled_from([0.1, 0.4, 0.9]))
def test_balanced_partition_properties(seed, shards, nn, density):
    """PROPERTY: for random occupancy maps, balanced ``max(shard_work)`` <=
    contiguous, tile_slot is a bijection over all N-tiles, and totals are
    conserved — including N-tile counts that don't divide the shard count
    (padding tiles join the packing)."""
    rng = np.random.default_rng(seed)
    occ = (rng.random((7, 1, nn)) < density).astype(np.int32)
    _check_balanced_properties(occ, shards)


def test_balanced_partition_properties_smoke():
    """Non-hypothesis fallback of the partitioner property: fixed skewed and
    adversarial cases run in every environment."""
    rng = np.random.default_rng(3)
    for nn, shards in ((8, 4), (5, 2), (7, 3), (16, 4)):
        occ = (rng.random((7, 1, nn)) < 0.4).astype(np.int32)
        _check_balanced_properties(occ, shards)


def test_balanced_never_worse_than_optimal_contiguous():
    """The greedy LPT packing alone can LOSE to a contiguous layout that
    happens to be optimal (LPT is a 4/3-approximation): per-tile counts
    [3,3,0,2,2,2] at 2 shards pack greedily to max 7 while the contiguous
    slabs hit the optimal 6.  Balanced mode must take the better of the
    two — pinned here so the property above can never regress."""
    occ = np.zeros((7, 1, 6), np.int32)
    for j, c in enumerate([3, 3, 0, 2, 2, 2]):
        occ[:c, 0, j] = 1
    kw = _occ_kw(occ)
    cont = shard_schedule(kw, 2)
    bal = shard_schedule(kw, 2, partition="balanced")
    assert max(cont.shard_work) == 6          # contiguous is optimal here
    assert max(bal.shard_work) == 6           # balanced must match it
    np.testing.assert_array_equal(np.asarray(bal.tile_slot), np.arange(6))


def _extreme_weight(case):
    k, nn = 512, 3                            # 3 N-tiles: N % 2 != 0 too
    if case == "all_empty":
        return jnp.zeros((k, nn * 128))
    if case == "all_dense":
        kk = jax.random.split(jax.random.PRNGKey(20), 2)
        return (jnp.sign(jax.random.normal(kk[0], (k, nn * 128)))
                * (0.5 + 0.5 * jax.random.uniform(kk[1], (k, nn * 128))))
    if case == "single_hot":
        w = jnp.zeros((k, nn * 128))
        hot = jax.random.normal(jax.random.PRNGKey(21), (k, 128)) * 0.05
        return w.at[:, 128:256].set(hot)
    if case == "ragged_sparse":
        return _sparse_w(22, k, nn * 128, sparsity=0.8)
    raise AssertionError(case)


@pytest.mark.parametrize("shards", [2, 3, 4])
@pytest.mark.parametrize("case", ["all_empty", "all_dense", "single_hot",
                                  "ragged_sparse"])
def test_balanced_sharded_bit_exact_extremes(case, shards):
    """PROPERTY (fixed extremes): balanced-sharded output, gathered back
    through tile_slot, is bit-exact against the unsharded Pallas kernel at
    every sparsity extreme — all-empty (zero work anywhere), all-dense
    (permutation of a full schedule), one hot tile (maximal skew), ragged
    sparse with N-tiles not dividing the shard count."""
    w = _extreme_weight(case)
    a = jax.random.normal(jax.random.PRNGKey(23), (8, 512))
    kw = knead(w, bits=8, ks=256, n_block=128)
    skw = shard_schedule(kw, shards, partition="balanced")
    out = sac_matmul_pallas_sharded(a, skw, bm=8)[:, :kw.n]
    ref = sac_matmul_pallas(a, kw, bm=8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    if case == "single_hot" and shards >= 3:
        # maximal skew: one tile holds ALL the work — no partition can
        # spread it, but balanced must not make it worse
        assert max(skw.shard_work) == skw.total_work


@given(seed=st.integers(0, 50), shards=st.sampled_from([2, 3, 4]))
def test_balanced_sharded_bit_exact_random(seed, shards):
    """PROPERTY: random column-structured sparsity → balanced-sharded ==
    unsharded, bitwise (the gather restores original column order and each
    tile's f32 accumulation sequence is untouched)."""
    rng = np.random.default_rng(seed)
    w = np.asarray(_sparse_w(seed, 512, 512, sparsity=0.5))
    # zero random whole N-blocks so tiles carry genuinely unequal work
    for j in range(4):
        if rng.random() < 0.5:
            w[:, j * 128:(j + 1) * 128] = 0.0
    a = jax.random.normal(jax.random.PRNGKey(seed + 7), (8, 512))
    kw = knead(jnp.asarray(w), bits=8)
    skw = shard_schedule(kw, shards, partition="balanced")
    out = sac_matmul_pallas_sharded(a, skw, bm=8)[:, :kw.n]
    ref = sac_matmul_pallas(a, kw, bm=8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ------------------- activation-side skip (two-sided; docs/DESIGN.md §12)
#
# The runtime half of the skip intersects per-K-tile activation presence
# into the static weight-side schedule.  The property wall: intersected
# work ⊆ weight-only work (with the packed-presence popcount agreeing),
# dropped items contribute exactly 0 to the replay oracle (work
# conservation), the activation extremes survive, and the masked Pallas
# walk stays bit-exact against planes AND the unskipped walk across random
# sparsities.

def _gappy_activation(seed, m, k, ks, dead_frac):
    """[m, k] activations with whole K-tiles zeroed (a dead-channel ReLU
    trace shape — elementwise sparsity alone never empties a 256-wide
    tile, so tile-granular skip needs structured gaps)."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    nk = k // ks
    dead = rng.random(nk) < dead_frac
    for t in np.nonzero(dead)[0]:
        a[:, t * ks:(t + 1) * ks] = 0.0
    return jnp.asarray(a)


def _check_intersection_invariants(kw, a):
    """Subset + packed-popcount agreement for one (weight, activation)."""
    pres = actocc.ktile_presence(a, kw.ks)
    sched = kw.schedule
    mask = np.asarray(actocc.work_mask(sched.counts, sched.ktile_ids, pres))
    base = np.asarray(actocc.weight_only_mask(sched.counts, sched.num_work))
    # intersected work ⊆ weight-only work, slot by slot
    assert ((mask == 0) | (base == 1)).all()
    assert mask.sum() <= base.sum() == sched.total_work
    # the packed-word view of the same intersection counts the same work
    inter = actocc.intersect_packed_presence(kw.occupancy, pres)
    assert int(np.asarray(popcount(inter)).sum()) == int(mask.sum())
    # per N-tile too, not just in aggregate
    per_tile = np.asarray(popcount(inter)).sum(axis=(0, 1))
    np.testing.assert_array_equal(per_tile, mask.sum(axis=1))
    return pres, mask


@given(seed=st.integers(0, 200),
       sparsity=st.sampled_from([0.0, 0.7]),
       dead_frac=st.sampled_from([0.0, 0.5, 1.0]))
def test_act_intersection_subset(seed, sparsity, dead_frac):
    """PROPERTY: for random weights and gappy activations, the intersected
    work list is a subset of the weight-only one and its size equals the
    popcount of the AND-ed packed presence words."""
    kw = knead(_sparse_w(seed, 512, 256, sparsity), bits=8)
    a = _gappy_activation(seed + 1, 2, 512, 256, dead_frac)
    _check_intersection_invariants(kw, a)


def test_act_intersection_subset_smoke():
    """Non-hypothesis fallback of the subset property: fixed cases covering
    no gaps, half gaps, and all-dead activations."""
    for seed, dead in ((0, 0.0), (1, 0.5), (2, 1.0)):
        kw = knead(_sparse_w(seed, 1024, 256, 0.6), bits=8, ks=256)
        a = _gappy_activation(seed + 9, 2, 1024, 256, dead)
        _check_intersection_invariants(kw, a)


@given(seed=st.integers(0, 100),
       sparsity=st.sampled_from([0.0, 0.8]),
       dead_frac=st.sampled_from([0.25, 0.5, 0.75]))
def test_act_skip_work_conservation(seed, sparsity, dead_frac):
    """PROPERTY (work conservation): the items the intersection drops
    contribute exactly 0 — the replay oracle over the intersected order is
    bit-identical to the full weight-only replay."""
    kw = knead(_sparse_w(seed, 1024, 128, sparsity), bits=8)
    a = _gappy_activation(seed + 3, 2, 1024, 256, dead_frac)
    pres, mask = _check_intersection_invariants(kw, a)
    full = replay_schedule(a, kw)
    skipped = replay_schedule(a, kw, act_presence=pres)
    np.testing.assert_array_equal(np.asarray(skipped), np.asarray(full))


def test_act_skip_work_conservation_smoke():
    """Non-hypothesis fallback of the conservation property: one case where
    the intersection provably drops work, replays bit-identical."""
    kw = knead(_sparse_w(11, 1024, 128, 0.5), bits=8)
    a = _gappy_activation(17, 1, 1024, 256, 0.5)
    pres, mask = _check_intersection_invariants(kw, a)
    assert mask.sum() < kw.schedule.total_work     # really dropped items
    full = replay_schedule(a, kw)
    skipped = replay_schedule(a, kw, act_presence=pres)
    np.testing.assert_array_equal(np.asarray(skipped), np.asarray(full))


@pytest.mark.parametrize("case", ["all_zero", "all_dense", "single_hot"])
def test_act_skip_activation_extremes(case):
    """Activation extremes: an all-zero activation drops EVERY item (output
    exactly zero), a fully-dense one drops none (mask == weight-only mask),
    and a single-hot one keeps exactly the one tile's items — all bit-exact
    against the unskipped kernel and the planes oracle."""
    kw = knead(_sparse_w(31, 1024, 256, 0.5), bits=8)
    sched = kw.schedule
    rng = np.random.default_rng(32)
    a = np.zeros((2, 1024), np.float32)
    if case == "all_dense":
        a = rng.normal(size=(2, 1024)).astype(np.float32)
    elif case == "single_hot":
        a[:, 256:512] = rng.normal(size=(2, 256)).astype(np.float32)
    a = jnp.asarray(a)
    pres = actocc.ktile_presence(a, kw.ks)
    mask = np.asarray(actocc.work_mask(sched.counts, sched.ktile_ids, pres))
    counts = np.asarray(sched.counts)
    kids = np.asarray(sched.ktile_ids)
    if case == "all_zero":
        assert mask.sum() == 0
    elif case == "all_dense":
        np.testing.assert_array_equal(
            mask, np.asarray(actocc.weight_only_mask(sched.counts,
                                                     sched.num_work)))
    else:
        expect = sum(int((kids[j, :counts[j]] == 1).sum())
                     for j in range(sched.n_tiles))
        assert mask.sum() == expect > 0
    on = sac_matmul_pallas(a, kw, bm=8, skip_activations=True)
    off = sac_matmul_pallas(a, kw, bm=8)
    ref = sac_matmul(a, kw, impl="planes")
    np.testing.assert_array_equal(np.asarray(on), np.asarray(off))
    np.testing.assert_array_equal(np.asarray(on[:, :kw.logical_n]),
                                  np.asarray(ref))
    if case == "all_zero":
        np.testing.assert_array_equal(np.asarray(on),
                                      np.zeros_like(np.asarray(on)))


@given(seed=st.integers(0, 100),
       sparsity=st.sampled_from([0.0, 0.7, 0.95]),
       dead_frac=st.sampled_from([0.0, 0.5]),
       m=st.sampled_from([1, 2, 8]))
def test_act_skip_parity_bit_exact(seed, sparsity, dead_frac, m):
    """PROPERTY: masked pallas == unmasked pallas == planes, bitwise, across
    random weight sparsities, activation gap fractions, and GEMV row
    counts."""
    kw = knead(_sparse_w(seed, 512, 128, sparsity), bits=8)
    a = _gappy_activation(seed + 5, m, 512, 256, dead_frac)
    on = sac_matmul(a, kw, impl="pallas", skip_activations=True)
    off = sac_matmul(a, kw, impl="pallas")
    ref = sac_matmul(a, kw, impl="planes")
    np.testing.assert_array_equal(np.asarray(on), np.asarray(off))
    np.testing.assert_array_equal(np.asarray(on), np.asarray(ref))


def test_act_skip_parity_smoke():
    """Non-hypothesis fallback of the skip-parity property, with the skip
    accounting checked: fewer executed than scheduled tile-dots, same
    bits."""
    kw = knead(_sparse_w(41, 1024, 128, 0.5), bits=8)
    a = _gappy_activation(43, 2, 1024, 256, 0.5)
    actocc.reset_skip_stats()
    on = sac_matmul(a, kw, impl="pallas", skip_activations=True)
    jax.block_until_ready(on)
    stats = actocc.skip_stats()
    assert stats["weight_tile_dots"] == kw.schedule.total_work
    assert stats["executed_tile_dots"] < stats["weight_tile_dots"]
    assert 0.0 < stats["act_skip_frac"] <= 1.0
    off = sac_matmul(a, kw, impl="pallas")
    ref = sac_matmul(a, kw, impl="planes")
    np.testing.assert_array_equal(np.asarray(on), np.asarray(off))
    np.testing.assert_array_equal(np.asarray(on), np.asarray(ref))


def test_act_skip_gemv_gate():
    """The sac_matmul switch is decode-GEMV-only: a prefill-shaped call
    (M > 8) must fall back to the static weight-only walk and record no
    skip traffic."""
    kw = knead(_sparse_w(51, 512, 128, 0.5), bits=8)
    a = _gappy_activation(53, 24, 512, 256, 0.5)
    actocc.reset_skip_stats()
    on = sac_matmul(a, kw, impl="pallas", skip_activations=True)
    jax.block_until_ready(on)
    assert actocc.skip_stats()["weight_tile_dots"] == 0    # gate held
    off = sac_matmul(a, kw, impl="pallas")
    np.testing.assert_array_equal(np.asarray(on), np.asarray(off))


@pytest.mark.parametrize("partition", ["contiguous", "balanced"])
def test_act_skip_sharded_bit_exact(partition):
    """Sharded execution with skip: the mask is computed once from the
    replicated activations and sliced per shard — serial shard walk stays
    bit-exact vs the skip-off walk and the unsharded kernel, under both
    partitions (the balanced tile_slot gather is untouched by masking)."""
    kw = knead(_sparse_w(61, 512, 512, 0.6), bits=8)
    a = _gappy_activation(63, 2, 512, 256, 0.5)
    skw = shard_schedule(kw, 2, partition=partition)
    on = sac_matmul_pallas_sharded(a, skw, None, bm=8, skip_activations=True)
    off = sac_matmul_pallas_sharded(a, skw, None, bm=8)
    ref = sac_matmul_pallas(a, kw, bm=8)
    np.testing.assert_array_equal(np.asarray(on), np.asarray(off))
    np.testing.assert_array_equal(np.asarray(on), np.asarray(ref))


# -------------------------------------------------- logical-K direct calls
def test_sac_matmul_pallas_accepts_logical_k():
    """Direct FC callers pass logical-K activations; padding happens inside
    (mirrors sac_conv2d) and parity with the oracle stays bit-exact."""
    w = jax.random.normal(jax.random.PRNGKey(3), (300, 100)) * 0.05
    a = jax.random.normal(jax.random.PRNGKey(4), (8, 300))
    kw = knead_padded(w, bits=8, ks=256)
    assert kw.k != 300                          # really padded
    out = sac_matmul_pallas(a, kw, bm=8)        # logical K accepted
    assert out.shape == (8, kw.n)
    ref = sac_matmul(a, kw, impl="planes")      # sliced to logical N
    np.testing.assert_array_equal(np.asarray(out[:, :100]), np.asarray(ref))
    try:
        sac_matmul_pallas(jax.random.normal(jax.random.PRNGKey(5), (8, 299)),
                          kw, bm=8)
    except ValueError as e:
        assert "neither" in str(e)
    else:
        raise AssertionError("mismatched K must raise")
