"""Continuous-batching scheduler invariants (docs/DESIGN.md §9).

The load-bearing guarantee is the acceptance bar of the scheduler PR:
for the same request set, the continuous step loop produces generations
**bit-identical** to the batch-synchronous drain() path — per-row decode
is independent of batch composition and padded cache extent, for the
planes and pallas impls alike.  Around that: admission order under
priority ties, cancel freeing KV blocks mid-decode, slot reuse being
bit-exact vs a fresh engine, the KV pool's reservation arithmetic, the
request-handle API, and the config impl-alias shims.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.configs.registry import get_config
from repro.inference.engine import ServingConfig, ServingEngine
from repro.inference.frontend import (DeadlineExceeded, RequestHandle,
                                      validate_buckets)
from repro.inference.kv_pool import KVBlockPool, PoolExhausted
from repro.models.lm import LanguageModel

MIN_DIM = 8      # knead smoke-size projections too


@pytest.fixture(scope="module")
def smol():
    cfg = get_config("smollm-360m", smoke=True)
    params = LanguageModel(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _engine(smol, scheduler="continuous", impl="float", **kw):
    cfg, params = smol
    defaults = dict(max_len=48, impl=impl, knead_min_dim=MIN_DIM,
                    buckets=(1, 2, 4), scheduler=scheduler, max_inflight=3,
                    kv_block=16)
    defaults.update(kw)
    return ServingEngine(cfg, params, ServingConfig(**defaults))


def _submit_set(eng, cfg, spec=((6, 5), (6, 3), (9, 4), (4, 1), (6, 6))):
    handles = []
    for i, (plen, n) in enumerate(spec):
        toks = jax.random.randint(jax.random.PRNGKey(50 + i), (plen,), 0,
                                  cfg.vocab_size)
        handles.append(eng.submit(toks, n))
    return handles


# ------------------------------------------------------------- KV pool


def test_kv_pool_reservations():
    pool = KVBlockPool(num_slots=4, max_len=64, block=16)
    assert pool.total_blocks == 16 and pool.extent() == 0
    t0 = pool.alloc(0, 40)                     # ceil(40/16) = 3 blocks
    assert len(t0) == 3 and pool.used_blocks == 3
    assert pool.slot_extent(0) == 48 and pool.extent() == 48
    pool.alloc(1, 10)
    assert pool.extent() == 48                 # high-water over live slots
    assert pool.free(0) == 3
    assert pool.extent() == 16                 # shrinks when the long one goes
    assert pool.free(0) == 0                   # double-free is a no-op
    with pytest.raises(ValueError):
        pool.alloc(1, 8)                       # slot already reserved


def test_kv_pool_exhaustion_and_fits():
    pool = KVBlockPool(num_slots=2, max_len=64, block=16, total_tokens=64)
    assert pool.fits(64) and not pool.fits(65)
    pool.alloc(0, 50)                          # 4 of 4 blocks
    assert not pool.can_admit(1)
    with pytest.raises(PoolExhausted):
        pool.alloc(1, 1)
    pool.free(0)
    assert pool.can_admit(64)


def test_kv_pool_dense_fallback():
    pool = KVBlockPool(num_slots=2, max_len=32, block=0)   # dense rows
    assert pool.block == 32 and pool.total_blocks == 2
    pool.alloc(0, 5)
    assert pool.slot_extent(0) == 32           # whole-row granularity


# ---------------------------------------------------- bucket validation


def test_validate_buckets_rejects_bad_inputs():
    with pytest.raises(ValueError, match="non-empty"):
        validate_buckets(())
    with pytest.raises(ValueError, match="ascending"):
        validate_buckets((4, 2))
    with pytest.raises(ValueError, match="ascending"):
        validate_buckets((0, 2))
    validate_buckets((1, 2, 8))                # fine


# --------------------------------------------------- config alias shims


def test_model_config_impl_alias_pinned():
    cfg = ModelConfig()
    assert cfg.impl == "int"                   # canonical field + default
    with pytest.warns(DeprecationWarning):
        legacy = ModelConfig(sac_impl="planes")
    assert legacy.impl == "planes"
    with pytest.warns(DeprecationWarning):
        via_replace = dataclasses.replace(cfg, sac_impl="pallas")
    assert via_replace.impl == "pallas"
    # canonical spelling round-trips silently and sticks through replace —
    # a stale alias copy must never clobber an explicit impl=
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        c2 = dataclasses.replace(cfg, impl="planes")
        c3 = dataclasses.replace(c2, num_layers=1)
    assert (c2.impl, c3.impl) == ("planes", "planes")
    # the alias is consumed at construction: normalized storage is None
    assert via_replace.sac_impl is None


def test_engine_threads_impl_into_model_config(smol):
    eng = _engine(smol, impl="int")
    assert eng.cfg.impl == "int"


# ------------------------------------------- continuous-vs-batch parity


@pytest.mark.parametrize("impl", ["planes", "pallas"])
def test_continuous_matches_batch_drain_bitwise(smol, impl):
    """The acceptance bar: identical request set, bit-identical tokens out
    of both schedulers, through both kneaded SAC execution paths."""
    cfg, _ = smol
    spec = ((6, 4), (6, 2), (9, 3), (4, 1))
    eb = _engine(smol, scheduler="batch", impl=impl)
    ec = _engine(smol, scheduler="continuous", impl=impl, max_inflight=2)
    hb, hc = _submit_set(eb, cfg, spec), _submit_set(ec, cfg, spec)
    rb, rc = eb.drain(), ec.drain()
    assert sorted(rb) == sorted(rc) == sorted(int(h) for h in hb)
    for rid in rb:
        assert np.array_equal(np.asarray(rb[rid]), np.asarray(rc[rid])), rid
    assert eb.drain() == {} and ec.drain() == {}


def test_slot_reuse_bit_exact_vs_fresh_engine(smol):
    """A second wave through recycled slots (and a shrunk-then-regrown KV
    pool) must match a fresh engine serving only that wave."""
    cfg, _ = smol
    wave2 = ((7, 4), (5, 3), (7, 2))
    used = _engine(smol)
    _submit_set(used, cfg)                     # wave 1 dirties every slot
    used.drain()
    fresh = _engine(smol)
    h_used = _submit_set(used, cfg, wave2)
    h_fresh = _submit_set(fresh, cfg, wave2)
    r_used, r_fresh = used.drain(), fresh.drain()
    for hu, hf in zip(h_used, h_fresh):
        assert np.array_equal(np.asarray(r_used[hu]),
                              np.asarray(r_fresh[hf]))


# ------------------------------------------------- scheduler invariants


def test_admission_order_priority_then_fifo(smol):
    """Higher priority admits first; FIFO (submit order) within a tie."""
    cfg, _ = smol
    eng = _engine(smol, max_inflight=1)        # serialize admissions
    p = jnp.arange(5) % cfg.vocab_size
    hs = [eng.submit(p, 2, priority=pr) for pr in (0, 7, 0, 7)]
    eng.drain()
    order = [int(h) for h in sorted(hs, key=lambda h: h._req.admit_tick)]
    assert order == [1, 3, 0, 2]


def test_cancel_mid_decode_frees_kv_blocks(smol):
    cfg, _ = smol
    eng = _engine(smol, max_inflight=2)
    p = jnp.arange(6) % cfg.vocab_size
    h1, h2 = eng.submit(p, 20), eng.submit(p, 20)
    eng.scheduler_step()
    pool = eng._scheduler.pool
    assert h1.state == h2.state == "running"
    before = pool.used_blocks
    assert h1.cancel() and h1.state == "cancelled"
    assert pool.used_blocks < before           # its reservation freed NOW
    assert not h1.cancel()                     # idempotent: already gone
    out = h2.result()                          # the survivor is unaffected
    assert out.shape == (20,)
    assert pool.used_blocks == 0
    with pytest.raises(RuntimeError, match="cancelled"):
        h1.result()


def test_streaming_yields_every_token_incrementally(smol):
    cfg, _ = smol
    eng = _engine(smol)
    p = jnp.arange(5) % cfg.vocab_size
    h = eng.submit(p, 6)
    it = h.stream()
    first = next(it)
    assert h.state == "running"                # only stepped as far as needed
    assert len(h.tokens_so_far()) < 6
    rest = list(it)
    assert [first] + rest == h.result().tolist()
    assert len(rest) == 5


def test_deadline_expires_queued_request(smol):
    import time
    cfg, _ = smol
    eng = _engine(smol)
    p = jnp.arange(4) % cfg.vocab_size
    doomed = eng.submit(p, 2, deadline=0.0)
    time.sleep(0.01)
    ok = eng.submit(p, 2)
    results = eng.drain()
    assert doomed.state == "expired"
    assert int(doomed) not in results and int(ok) in results
    with pytest.raises(DeadlineExceeded):
        doomed.result()


def test_pool_budget_gates_admission_but_all_complete(smol):
    """A pool smaller than the slot table forces serialized admission —
    every request still completes, identically to an unconstrained run."""
    cfg, _ = smol
    tight = _engine(smol, max_inflight=3, kv_pool_tokens=32, kv_block=16)
    roomy = _engine(smol, max_inflight=3)
    spec = ((6, 4), (6, 3), (6, 2))
    ht, hr = _submit_set(tight, cfg, spec), _submit_set(roomy, cfg, spec)
    rt, rr = tight.drain(), roomy.drain()
    assert sorted(rt) == sorted(rr)
    for a, b in zip(ht, hr):
        assert np.array_equal(np.asarray(rt[a]), np.asarray(rr[b]))
    # and a request that could NEVER fit the pool fails loudly at submit
    with pytest.raises(ValueError, match="pool"):
        tight.submit(jnp.arange(30) % cfg.vocab_size, 10)


# --------------------------------------------------- request-handle API


def test_handle_is_int_compatible(smol):
    cfg, _ = smol
    eng = _engine(smol)
    hs = _submit_set(eng, cfg, ((4, 2), (4, 2)))
    assert all(isinstance(h, (int, RequestHandle)) for h in hs)
    assert sorted(hs) == [0, 1] and hs[0] == 0 and {hs[0]: "x"}[0] == "x"
    assert hs[1].priority == 0 and hs[1].deadline is None
    results = eng.drain()
    assert np.array_equal(np.asarray(results[hs[0]]),
                          np.asarray(hs[0].result()))


def test_batch_mode_handles_and_latency_breakdown(smol):
    """The handle API works on the wave-synchronous path too (result()
    drains), and latency_stats grows the queue-wait/decode split."""
    cfg, _ = smol
    eng = _engine(smol, scheduler="batch")
    hs = _submit_set(eng, cfg, ((4, 2), (4, 3)))
    out = hs[0].result()                       # triggers a full drain
    assert out.shape == (2,) and hs[1].state == "done"
    assert list(hs[1].stream()) == hs[1].result().tolist()
    stats = eng.latency_stats()
    for key in ("queue_wait_p50_ms", "queue_wait_p95_ms",
                "decode_p50_ms", "decode_p95_ms", "p95_ms"):
        assert key in stats
    with pytest.raises(ValueError, match="continuous"):
        eng.scheduler_step()


def test_submit_validation_errors(smol):
    cfg, _ = smol
    eng = _engine(smol)
    with pytest.raises(ValueError, match="one prompt"):
        eng.submit(jnp.zeros((2, 4), jnp.int32), 2)
    with pytest.raises(ValueError, match="num_tokens"):
        eng.submit(jnp.arange(4), 0)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(jnp.arange(40), 20)
    with pytest.raises(ValueError, match="at least one token"):
        eng.submit(jnp.zeros((0,), jnp.int32), 2)


def test_continuous_rejects_side_input_families():
    cfg = get_config("llama-3.2-vision-90b", smoke=True)
    params = LanguageModel(cfg).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="batch"):
        ServingEngine(cfg, params,
                      ServingConfig(max_len=64, impl="float",
                                    scheduler="continuous"))


def test_cnn_submit_validates_image_shape():
    from repro.inference.cnn_engine import CNNServingConfig, CNNServingEngine
    from repro.models import cnn

    cfg = dataclasses.replace(cnn.CNN_ZOO["alexnet"], image_size=16)
    params = cnn.init(jax.random.PRNGKey(0), cfg)
    eng = CNNServingEngine(cfg, params, CNNServingConfig(impl="int"))
    with pytest.raises(ValueError, match="does not match"):
        eng.submit(jnp.zeros((8, 8, 3)))       # wrong H, W
    with pytest.raises(ValueError, match="does not match"):
        eng.submit(jnp.zeros((16, 16, 1)))     # wrong channels
    h = eng.submit(jnp.zeros((16, 16, 3)))
    out = eng.drain()
    assert np.array_equal(np.asarray(out[h]), np.asarray(h.result()))
    assert "queue_wait_p50_ms" in eng.latency_stats()
