"""Sharded kneaded serving: schedule partitioning, shard_map parity, batching.

Covers the docs/DESIGN.md §5 path end to end: ``shard_schedule`` structure
(including N-tiles that don't divide the shard count and shards whose work
lists are entirely empty), bit-exact parity of the shard_map-launched Pallas
kernel against the serial single-device shard walk, the full-AlexNet
multi-device acceptance criterion, and the engine's padding-bucket batched
front end.

Oracle note: forcing many host devices re-partitions XLA CPU's matmul
threading, which perturbs the f32 reduction order of the *dense jnp* planes
oracle (measured: bit-identical at 1-2 forced devices, ~1e-6 drift at 4).
The schedule-walking Pallas kernel is bit-stable across device counts, so
the multi-device test compares sharded-pallas (N-device subprocess) against
the planes oracle computed where it is well-defined — a clean single-device
subprocess — exactly the "sharded pallas == single-device planes oracle"
criterion.  In-process assertions under a forced-device environment compare
pallas against pallas for the same reason.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kneading import knead, knead_padded
from repro.core.sac import sac_matmul
from repro.core.schedule import build_schedule, shard_schedule
from repro.inference.cnn_engine import CNNServingConfig, CNNServingEngine
from repro.kernels.sac_matmul.ops import (sac_conv2d, sac_matmul_pallas,
                                          sac_matmul_pallas_sharded)
from repro.models import cnn


def _sparse_w(seed, k, n, sparsity=0.0):
    kk = jax.random.split(jax.random.PRNGKey(seed), 2)
    w = jax.random.normal(kk[0], (k, n)) * 0.05
    if sparsity > 0:
        keep = jax.random.uniform(kk[1], (k, n)) >= sparsity
        w = w * keep
    return w


# ------------------------------------------------------------- structure

def test_shard_schedule_splits_work_lists():
    """Shards take contiguous N-tile slabs with exactly those tiles' work
    lists; per-shard occupancy totals partition the unsharded total."""
    rng = np.random.default_rng(0)
    occ = (rng.random((7, 5, 8)) < 0.3).astype(np.int32)
    kw = knead(_sparse_w(1, 5 * 256, 8 * 128), bits=8).with_occupancy(
        jnp.asarray(occ))
    skw = shard_schedule(kw, 4)
    sched = kw.schedule
    assert skw.num_shards == 4 and skw.tiles_per_shard == 2
    assert skw.num_work == sched.num_work
    assert skw.total_work == sched.total_work
    assert sum(skw.shard_work) == int(occ.sum())
    for s in range(4):
        sub = skw.schedule_for(s)
        tiles = slice(2 * s, 2 * s + 2)
        np.testing.assert_array_equal(np.asarray(sub.counts),
                                      np.asarray(sched.counts)[tiles])
        np.testing.assert_array_equal(np.asarray(sub.plane_ids),
                                      np.asarray(sched.plane_ids)[tiles])
        np.testing.assert_array_equal(np.asarray(sub.ktile_ids),
                                      np.asarray(sched.ktile_ids)[tiles])
        assert sub.total_work == int(occ[:, :, tiles].sum())
    # weight slabs are the matching contiguous column slices
    for s in range(4):
        np.testing.assert_array_equal(
            np.asarray(skw.planes[s]),
            np.asarray(kw.planes)[:, :, s * 256:(s + 1) * 256])


def test_shard_schedule_indivisible_tiles():
    """N-tiles not divisible by the shard count: all-empty padding tiles are
    appended (count 0, zero columns) and parity stays bit-exact after the
    logical-N slice."""
    w = _sparse_w(2, 512, 384)               # 3 N-tiles
    a = jax.random.normal(jax.random.PRNGKey(3), (8, 512))
    kw = knead(w, bits=8)
    skw = shard_schedule(kw, 2)
    assert skw.tiles_per_shard == 2 and skw.n == 512  # 3 -> 4 tiles
    assert skw.logical_n == 384
    assert skw.total_work == kw.schedule.total_work   # padding adds no work
    out = sac_matmul_pallas_sharded(a, skw, bm=8)[:, :skw.logical_n]
    ref = sac_matmul_pallas(a, kw, bm=8)[:, :384]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_shard_schedule_empty_shard():
    """A shard whose entire work list is empty executes nothing and writes
    zeros (its columns are all-zero), while other shards are unaffected."""
    w = _sparse_w(4, 512, 512).at[:, 256:].set(0.0)
    a = jax.random.normal(jax.random.PRNGKey(5), (8, 512))
    kw = knead(w, bits=8)
    skw = shard_schedule(kw, 2)
    assert skw.shard_work[1] == 0 and skw.shard_work[0] > 0
    imb = skw.imbalance()
    assert imb["shard_work"] == [skw.shard_work[0], 0]
    assert imb["imbalance"] == pytest.approx(2.0)
    out = sac_matmul_pallas_sharded(a, skw, bm=8)
    np.testing.assert_array_equal(np.asarray(out[:, 256:]),
                                  np.zeros((8, 256), np.float32))
    ref = sac_matmul_pallas(a, kw, bm=8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_shard_schedule_all_empty():
    """All-zero weights shard into all-empty work lists on every device."""
    kw = knead(jnp.zeros((512, 256)), bits=8)
    skw = shard_schedule(kw, 2)
    assert skw.shard_work == (0, 0) and skw.total_work == 0
    a = jax.random.normal(jax.random.PRNGKey(0), (8, 512))
    out = sac_matmul_pallas_sharded(a, skw, bm=8)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.zeros((8, 256), np.float32))


def test_shard_schedule_structure_from_occupancy():
    """Sharding commutes with schedule building: shard s of the full
    schedule == the schedule built from shard s's occupancy columns, up to
    work-dim padding width."""
    rng = np.random.default_rng(7)
    occ = (rng.random((3, 4, 6)) < 0.4).astype(np.int32)
    kw = knead(_sparse_w(8, 4 * 256, 6 * 128, 0.0), bits=4).with_occupancy(
        jnp.asarray(occ))
    skw = shard_schedule(kw, 3)
    for s in range(3):
        local = build_schedule(occ[:, :, 2 * s:2 * s + 2])
        sub = skw.schedule_for(s)
        np.testing.assert_array_equal(np.asarray(sub.counts),
                                      np.asarray(local.counts))
        w = local.num_work          # sub pads the work dim to the global max
        np.testing.assert_array_equal(np.asarray(sub.plane_ids[:, :w]),
                                      np.asarray(local.plane_ids))
        np.testing.assert_array_equal(np.asarray(sub.ktile_ids[:, :w]),
                                      np.asarray(local.ktile_ids))


# --------------------------------------------------------- serial parity

@pytest.mark.parametrize("shards", [1, 2, 4])
def test_serial_sharded_matmul_bit_exact(shards):
    """The serial shard walk (mesh=None) is bit-exact against the unsharded
    kernel for any shard count — each shard replays its N-tiles' work lists
    in the single-device order."""
    w = _sparse_w(10, 512, 512, sparsity=0.7)
    a = jax.random.normal(jax.random.PRNGKey(11), (8, 512))
    kw = knead(w, bits=8)
    skw = shard_schedule(kw, shards)
    out = sac_matmul_pallas_sharded(a, skw, bm=8)
    ref = sac_matmul_pallas(a, kw, bm=8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    if jax.device_count() == 1:
        # the dense jnp oracle is only bitwise-well-defined on one device
        # (see module docstring); the multi-device CI job skips this leg
        planes = sac_matmul(a, kw, impl="planes")
        np.testing.assert_array_equal(np.asarray(out[:, :kw.logical_n]),
                                      np.asarray(planes))


@pytest.mark.parametrize("partition", ["contiguous", "balanced"])
def test_sharded_conv2d_bit_exact(partition):
    """sac_conv2d with a sharded im2col filter == unsharded pallas conv,
    under either tile->shard partitioning."""
    x = jax.random.normal(jax.random.PRNGKey(12), (2, 10, 10, 8))
    w = _sparse_w(13, 72, 200)
    kw = knead_padded(w, bits=8)
    skw = shard_schedule(kw, 2, partition=partition)
    out = sac_conv2d(x, skw, ksize=3, impl="pallas")
    ref = sac_conv2d(x, kw, ksize=3, impl="pallas")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    with pytest.raises(ValueError, match="Pallas kernel only"):
        sac_conv2d(x, skw, ksize=3, impl="planes")


# ---------------------------------------- balanced repartitioning pins

def _occ_kw(occ):
    """All-zero weight whose schedule is forced to a crafted occupancy."""
    occ = np.asarray(occ, dtype=np.int32)
    nb, nk, nn = occ.shape
    kw = knead(jnp.zeros((nk * 256, nn * 128)), bits=nb + 1)
    return kw.with_occupancy(jnp.asarray(occ))


def test_balanced_repartition_pins_roadmap_skew():
    """REGRESSION PIN: the ROADMAP's skewed ``[14, 7, 0, 0]`` contiguous
    layer (per-tile counts 4,4,3,3,2,2,2,1 then eight empty tiles)
    repartitions under ``partition="balanced"`` to max-work
    ceil(21/4) = 6 — imbalance 2.67 -> ~1.14 (<= the 1.15 acceptance
    bound)."""
    counts = [4, 4, 3, 3, 2, 2, 2, 1] + [0] * 8
    occ = np.zeros((7, 1, 16), np.int32)
    for j, c in enumerate(counts):
        occ[:c, 0, j] = 1
    kw = _occ_kw(occ)
    cont = shard_schedule(kw, 4)
    assert list(cont.shard_work) == [14, 7, 0, 0]
    assert cont.imbalance()["imbalance"] == pytest.approx(14 / 5.25)
    bal = shard_schedule(kw, 4, partition="balanced")
    assert max(bal.shard_work) == 6            # == ceil(21 / 4)
    assert sum(bal.shard_work) == 21           # work conserved
    assert bal.imbalance()["imbalance"] == pytest.approx(6 / 5.25)
    assert bal.imbalance()["imbalance"] <= 1.15


def test_balanced_padding_tiles_participate():
    """Padding tiles from an indivisible N-tile count enter the packing as
    zero-work filler: they never inflate any shard's work, and the
    balanced max reaches ceil(total/S) where contiguous slabs are stuck
    carrying the heavy prefix."""
    counts = [5, 4, 3, 2, 1]                   # 5 tiles -> padded to 6 at S=2
    occ = np.zeros((7, 1, 5), np.int32)
    for j, c in enumerate(counts):
        occ[:c, 0, j] = 1
    kw = _occ_kw(occ)
    cont = shard_schedule(kw, 2)
    assert cont.tiles_per_shard == 3
    assert list(cont.shard_work) == [12, 3]
    bal = shard_schedule(kw, 2, partition="balanced")
    assert sum(bal.shard_work) == 15           # the pad tile added no work
    assert max(bal.shard_work) == 8            # == ceil(15 / 2)
    slot = np.asarray(bal.tile_slot)
    assert sorted(slot.tolist()) == list(range(6))   # pad tile packed too


def test_balanced_indivisible_bit_exact():
    """Balanced packing with a padding tile in play stays bit-exact after
    the logical-N slice."""
    w = _sparse_w(20, 512, 640, sparsity=0.6)  # 5 N-tiles
    a = jax.random.normal(jax.random.PRNGKey(21), (8, 512))
    kw = knead(w, bits=8)
    skw = shard_schedule(kw, 2, partition="balanced")
    assert skw.logical_n == 640
    out = sac_matmul_pallas_sharded(a, skw, bm=8)[:, :skw.logical_n]
    ref = sac_matmul_pallas(a, kw, bm=8)[:, :640]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ------------------------------------------- multi-device acceptance test

_ORACLE = textwrap.dedent("""
    import dataclasses, json, sys
    import jax, numpy as np
    from repro.inference.cnn_engine import CNNServingConfig, CNNServingEngine
    from repro.models import cnn
    cfg = dataclasses.replace(cnn.CNN_ZOO["alexnet"], image_size=16)
    params = cnn.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    eng = CNNServingEngine(cfg, params,
                           CNNServingConfig(impl="planes", jit=False))
    np.save(sys.argv[1], np.asarray(eng.logits(x)))
    print(json.dumps({"devices": jax.device_count()}))
""")

_SHARDED = textwrap.dedent("""
    import dataclasses, json, sys
    import jax, numpy as np
    from repro.inference.cnn_engine import CNNServingConfig, CNNServingEngine
    from repro.models import cnn
    cfg = dataclasses.replace(cnn.CNN_ZOO["alexnet"], image_size=16)
    params = cnn.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    shards = jax.device_count()
    partition = sys.argv[2] if len(sys.argv) > 2 else "contiguous"
    assert shards >= 2, "multi-device run needs forced host devices"
    eng = CNNServingEngine(cfg, params, CNNServingConfig(
        impl="pallas", jit=False, shards=shards,
        shard_partition=partition))
    out = np.asarray(eng.logits(x))
    # in-process cross-check against the unsharded kernel (bit-stable
    # across device counts, unlike the dense jnp oracle)
    ref = np.asarray(CNNServingEngine(cfg, params, CNNServingConfig(
        impl="pallas", jit=False)).logits(x))
    assert np.array_equal(out, ref), "sharded != unsharded pallas"
    rep = eng.layer_report()
    np.save(sys.argv[1], out)
    print(json.dumps({
        "devices": shards,
        "total_work": sum(r["executed_tile_dots"] for r in rep),
        "max_imbalance": max(r["shard_imbalance"] for r in rep),
    }))
""")


def _run(code, out_path, extra_env, *extra_args):
    env = {"PYTHONPATH": "src", "PATH": os.environ.get("PATH",
                                                       "/usr/bin:/bin")}
    env.update(extra_env)
    res = subprocess.run([sys.executable, "-c", code, out_path,
                          *extra_args],
                         capture_output=True, text=True, env=env,
                         cwd=".", timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


@pytest.fixture(scope="module")
def cnn_oracle(tmp_path_factory):
    """The clean single-device planes-oracle logits, computed ONCE for the
    whole partition parametrization (the oracle command is identical)."""
    path = tmp_path_factory.mktemp("cnn_oracle") / "oracle.npy"
    meta = _run(_ORACLE, str(path), {"JAX_PLATFORMS": "cpu"})
    assert meta["devices"] == 1
    return np.load(path)


@pytest.mark.parametrize("partition", ["contiguous", "balanced"])
def test_sharded_alexnet_bit_exact_vs_single_device_oracle(
        tmp_path, cnn_oracle, partition):
    """ACCEPTANCE: a full AlexNet forward, every layer's schedule sharded
    over >=2 forced host devices and launched under shard_map — under
    either tile->shard partitioning — is bit-exact against the planes
    oracle computed on a clean single device."""
    n_force = int(os.environ.get("REPRO_SHARD_TEST_DEVICES", "4"))
    sharded_meta = _run(
        _SHARDED, str(tmp_path / "sharded.npy"),
        {"XLA_FLAGS": f"--xla_force_host_platform_device_count={n_force}",
         "JAX_PLATFORMS": "cpu"}, partition)
    assert sharded_meta["devices"] == n_force
    out = np.load(tmp_path / "sharded.npy")
    np.testing.assert_array_equal(out, cnn_oracle)
    assert sharded_meta["total_work"] > 0
    assert sharded_meta["max_imbalance"] >= 1.0


# -------------------------------------------------- batched front end

def _nin16():
    import dataclasses
    return dataclasses.replace(cnn.CNN_ZOO["nin"], image_size=16)


def test_engine_submit_drain_matches_batch_logits():
    cfg = _nin16()
    params = cnn.init(jax.random.PRNGKey(0), cfg)
    eng = CNNServingEngine(cfg, params,
                           CNNServingConfig(impl="int", buckets=(2, 4)))
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 16, 16, 3))
    ids = [eng.submit(xs[i]) for i in range(5)]
    res = eng.drain()
    assert sorted(res) == sorted(ids)
    ref = eng.logits(xs)
    for i, rid in enumerate(ids):
        # allclose, not bitwise: the drain chunks run at bucket shapes
        # (4 and 2), and XLA CPU's threading partitions dense matmuls
        # differently per batch shape — ~1e-7-level f32 drift vs the
        # batch-5 reference (amplified under forced host devices)
        np.testing.assert_allclose(np.asarray(res[rid]),
                                   np.asarray(ref[i]),
                                   rtol=1e-5, atol=1e-5)
    stats = eng.latency_stats()
    assert stats["requests"] == 5
    assert stats["p95_ms"] >= stats["p50_ms"] > 0
    # 5 requests over buckets (2,4): chunks of 4 + 1->2 padded
    assert stats["mean_batch_fill"] == pytest.approx((4 * 1.0 + 0.5) / 5)
    assert eng.drain() == {}                 # queue fully drained


def test_engine_bucket_underfill():
    """A request count that fills no bucket exactly still pads up to the
    next bucket and serves every request correctly."""
    cfg = _nin16()
    params = cnn.init(jax.random.PRNGKey(0), cfg)
    eng = CNNServingEngine(cfg, params,
                           CNNServingConfig(impl="int", buckets=(4,)))
    xs = jax.random.normal(jax.random.PRNGKey(2), (3, 16, 16, 3))
    ids = [eng.submit(xs[i]) for i in range(3)]
    res = eng.drain()
    # bitwise against the same padded-bucket shape drain itself runs
    # (batch 4); cross-shape comparisons are only allclose (see above)
    ref = eng.logits(jnp.pad(xs, ((0, 1), (0, 0), (0, 0), (0, 0))))
    for i, rid in enumerate(ids):
        np.testing.assert_array_equal(np.asarray(res[rid]),
                                      np.asarray(ref[i]))
    log = list(eng._request_log)
    assert all(r["bucket"] == 4 for r in log)
    assert all(r["batch_fill"] == pytest.approx(0.75) for r in log)


def test_engine_submit_rejects_batched_input():
    cfg = _nin16()
    params = cnn.init(jax.random.PRNGKey(0), cfg)
    eng = CNNServingEngine(cfg, params, CNNServingConfig(impl="int"))
    with pytest.raises(ValueError, match="one image"):
        eng.submit(jnp.zeros((2, 16, 16, 3)))


def test_engine_sharded_requires_pallas():
    cfg = _nin16()
    params = cnn.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="single-device only"):
        CNNServingEngine(cfg, params,
                         CNNServingConfig(impl="int", shards=2))


# ------------------------------------- keep_float_params=False regression

def test_layer_report_without_float_checkpoint():
    """keep_float_params=False must not crash layer_report: codes fall back
    to exact reconstruction from the packed planes, and every statistic
    matches the float-checkpoint path bit-for-bit."""
    cfg = _nin16()
    params = cnn.init(jax.random.PRNGKey(0), cfg)
    lean = CNNServingEngine(
        cfg, params, CNNServingConfig(impl="int", keep_float_params=False))
    assert lean.float_params is None
    full = CNNServingEngine(cfg, params, CNNServingConfig(impl="int"))
    r_lean, r_full = lean.layer_report(), full.layer_report()
    assert len(r_lean) == len(r_full) == len(params)
    for a, b in zip(r_lean, r_full):
        assert a["layer"] == b["layer"]
        assert a["executed_tile_dots"] == b["executed_tile_dots"]
        assert a["cycle_ratio"] == pytest.approx(b["cycle_ratio"], abs=0.0)
