"""End-to-end behaviour tests for the full system (the paper's technique as
a serving feature + training loop integration)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.inference.engine import (ServingConfig, ServingEngine,
                                    knead_params, serving_bytes)
from repro.models.lm import LanguageModel


@pytest.fixture(scope="module")
def small_lm():
    """An LM large enough (>=128-dim projections) for kneading to apply."""
    cfg = dataclasses.replace(
        get_config("llama3-8b", smoke=True),
        d_model=256, num_heads=4, num_kv_heads=2, d_ff=512, num_layers=2)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_knead_params_reduces_serving_bytes(small_lm):
    cfg, model, params = small_lm
    b_f = serving_bytes(params)
    b_8 = serving_bytes(knead_params(params, bits=8))
    b_4 = serving_bytes(knead_params(params, bits=4))
    assert b_8 < 0.62 * b_f          # ~0.5x + embeddings/norms stay bf16
    assert b_4 < b_8


def test_kneaded_logits_close(small_lm):
    cfg, model, params = small_lm
    batch = {"tokens": jnp.arange(64, dtype=jnp.int32).reshape(2, 32)
             % cfg.vocab_size}
    lf = model.logits(params, batch).astype(jnp.float32)
    l8 = model.logits(knead_params(params, bits=8), batch).astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(lf - l8)) / (jnp.max(jnp.abs(lf)) + 1e-9))
    assert rel < 0.12                 # int8 kneading: small logit drift


def test_generation_across_precisions(small_lm):
    cfg, model, params = small_lm
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                 cfg.vocab_size)
    outs = {}
    for bits in (0, 8):
        eng = ServingEngine(cfg, params,
                            ServingConfig(max_len=48, quant_bits=bits))
        outs[bits] = eng.generate({"tokens": prompts}, 12)
    agree = float(jnp.mean((outs[8] == outs[0]).astype(jnp.float32)))
    assert agree > 0.6                # int8 mostly matches bf16 greedy


def test_prefill_decode_generation_consistency(small_lm):
    """Generating token-by-token must equal argmax over full forwards."""
    cfg, model, params = small_lm
    eng = ServingEngine(cfg, params, ServingConfig(max_len=48))
    prompts = jax.random.randint(jax.random.PRNGKey(5), (1, 8), 0,
                                 cfg.vocab_size)
    gen = eng.generate({"tokens": prompts}, 6)
    # reference: greedy with full forward each step
    toks = prompts
    ref = []
    for _ in range(6):
        logits = model.logits(params, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        ref.append(nxt)
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    ref = jnp.stack(ref, 1)
    assert float(jnp.mean((gen == ref).astype(jnp.float32))) > 0.8


def test_example_loss_descends():
    """The synthetic stream is learnable: 60 steps must cut the loss."""
    from repro.optim.adamw import AdamWConfig
    from repro.train.step import TrainStepConfig
    from repro.train.trainer import Trainer, TrainerConfig
    import shutil
    shutil.rmtree("/tmp/repro_test_descend", ignore_errors=True)
    cfg = get_config("smollm-360m", smoke=True)
    tr = Trainer(cfg, TrainerConfig(num_steps=60, ckpt_every=1000,
                                    ckpt_dir="/tmp/repro_test_descend",
                                    log_every=59),
                 ts=TrainStepConfig(optimizer=AdamWConfig(
                     lr=2e-3, warmup_steps=10, total_steps=60)),
                 global_batch=8, seq_len=64)
    log = tr.run()
    steps = sorted(log)
    assert log[steps[-1]]["loss"] < log[steps[0]]["loss"] - 0.3
