"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Every Pallas kernel runs in interpret mode (CPU container; TPU is the
target) and must match its ref.py to f32-matmul tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import knead, quantize
from repro.kernels.kneaded_gemm.ops import kneaded_gemm
from repro.kernels.kneaded_gemm.ref import kneaded_gemm_ref, pack_int4, unpack_int4
from repro.kernels.sac_matmul.ops import sac_matmul_pallas
from repro.kernels.sac_matmul.ref import sac_matmul_ref


def _wa(seed, m, k, n, dtype=jnp.float32):
    kk = jax.random.split(jax.random.PRNGKey(seed), 2)
    w = jax.random.normal(kk[0], (k, n)) * 0.04
    a = jax.random.normal(kk[1], (m, k)).astype(dtype)
    return w, a


SHAPES = [
    (1, 256, 128),      # gemv-like (decode)
    (8, 256, 256),
    (16, 512, 128),
    (128, 512, 256),    # multi-tile M
]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("bits", [4, 8, 9, 16])   # incl. odd width (paper §III.3)
def test_sac_kernel_shapes_bits(m, k, n, bits):
    w, a = _wa(bits * 100 + m, m, k, n)
    kw = knead(w, bits=bits, ks=256, n_block=128)
    ref = sac_matmul_ref(a, kw)
    out = sac_matmul_pallas(a, kw, bm=min(128, max(8, m)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("adtype", [jnp.float32, jnp.bfloat16])
def test_sac_kernel_activation_dtypes(adtype):
    w, a = _wa(7, 8, 256, 128, dtype=adtype)
    kw = knead(w, bits=8, ks=256, n_block=128)
    ref = sac_matmul_ref(a.astype(jnp.float32), kw)
    out = sac_matmul_pallas(a, kw, bm=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_sac_kernel_occupancy_skipping_exact():
    """Zeroed high planes must not change the result (skipped, not wrong).

    The second K-block is ~100x smaller than the first; with per-channel
    scales set by the large block, its codes have empty high planes -> its
    (plane, K-tile) occupancy entries go to zero and the kernel skips them.
    """
    w, a = _wa(9, 8, 512, 128)
    w = w.at[256:].multiply(0.01)
    kw = knead(w, bits=16, ks=256, n_block=128)
    occ = np.asarray(kw.occupancy_map())
    assert occ.sum() < occ.size       # some tiles actually skip
    # the schedule dispatches exactly the occupied tiles, nothing more
    assert kw.schedule.total_work == int(occ.sum())
    assert kw.schedule.total_work < kw.schedule.dense_work(kw.bits)
    out = sac_matmul_pallas(a, kw, bm=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(sac_matmul_ref(a, kw)),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_kneaded_gemm_int8(m, k, n):
    w, a = _wa(m + k, m, k, n)
    qt = quantize(w, bits=8)
    scale = qt.scale.reshape(1, -1)
    ref = kneaded_gemm_ref(a, qt.q, scale)
    out = kneaded_gemm(a, qt.q, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_kneaded_gemm_int4_packed(m, k, n):
    w, a = _wa(m + k + 1, m, k, n)
    qt = quantize(w, bits=4)
    packed = pack_int4(qt.q)
    assert packed.shape == (k // 2, n)
    scale = qt.scale.reshape(1, -1)
    ref = kneaded_gemm_ref(a, packed, scale, packed4=True)
    out = kneaded_gemm(a, packed, scale, packed4=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_int4_pack_roundtrip():
    q = jnp.arange(-8, 8, dtype=jnp.int8).reshape(16, 1)
    q = jnp.tile(q, (2, 3))
    assert bool(jnp.array_equal(unpack_int4(pack_int4(q)), q))


def test_kernel_bytes_reduction():
    """The kneaded format's HBM footprint: bits/16 of bf16 + metadata."""
    w, _ = _wa(3, 1, 1024, 256)
    kw8 = knead(w, bits=8, ks=256)
    kw16 = knead(w, bits=16, ks=256)
    dense = kw8.dense_bf16_bytes()
    assert kw8.packed_bytes() < 0.75 * dense
    assert kw16.packed_bytes() < 1.5 * dense
    assert kw8.packed_bytes() < kw16.packed_bytes()
