"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Every Pallas kernel runs in interpret mode (CPU container; TPU is the
target) and must match its ref.py to f32-matmul tolerance.  Cross-impl
parity (float/int/planes/pallas agreement) comes from the shared
``parity`` harness — the sweep below and the per-bit-width cases both run
through it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import parity
import pytest

from repro.core import knead, quantize
from repro.kernels.kneaded_gemm.ops import kneaded_gemm
from repro.kernels.kneaded_gemm.ref import kneaded_gemm_ref, pack_int4, unpack_int4
from repro.kernels.sac_matmul.ops import _pad_activations, sac_matmul_pallas
from repro.kernels.sac_matmul.ref import sac_matmul_ref


def _wa(seed, m, k, n, dtype=jnp.float32):
    kk = jax.random.split(jax.random.PRNGKey(seed), 2)
    w = jax.random.normal(kk[0], (k, n)) * 0.04
    a = jax.random.normal(kk[1], (m, k)).astype(dtype)
    return w, a


SHAPES = [
    (1, 256, 128),      # gemv (decode batch 1)
    (8, 256, 256),
    (16, 512, 128),
    (128, 512, 256),    # multi-tile M
]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("bits", [4, 8, 9, 16])   # incl. odd width (paper §III.3)
def test_sac_kernel_shapes_bits(m, k, n, bits):
    parity.run_case(bits * 100 + m, m, k, n, bits=bits)


# the canonical cross-impl sweep (hypothesis-gated), kernel-tile shape pool
test_sac_impl_parity_sweep = parity.make_sweep_test()


# ------------------------------------------------- decode-GEMV M edge cases

@pytest.mark.parametrize("m", [1, 2, 7, 8, 9, 12])
def test_sac_kernel_tiny_m_bit_exact(m):
    """The M<8 clamp / small-M fast path must stay bit-exact vs the planes
    oracle — decode serves M=batch rows, often 1."""
    parity.run_case(m, m, 512, 128)


def test_pad_activations_m_policy():
    """bm_eff = min(bm, M rounded to the 8-row sublane floor): tiny M runs
    one small block, mid M an aligned single block, large M the full
    streamed grid; the padded row count is always a bm_eff multiple."""
    w, _ = _wa(0, 1, 512, 128)
    kw = knead(w, bits=8, ks=256, n_block=128)
    cases = [  # (m, bm) -> expected bm_eff
        (1, 256, 8), (7, 256, 8), (8, 256, 8),      # M<8 clamps to the floor
        (9, 256, 16), (12, 256, 16),                # round up, single block
        (40, 256, 40), (300, 256, 256),             # large M: streamed grid
        (5, 8, 8),                                  # caller cap respected
    ]
    for m, bm, want in cases:
        a = jnp.ones((m, 512))
        padded, m_out, bm_eff = _pad_activations(a, kw, bm)
        assert bm_eff == want, (m, bm, bm_eff, want)
        assert m_out == m
        assert padded.shape[0] % bm_eff == 0 and bm_eff % 8 == 0


def test_pad_activations_logical_k():
    """Logical-K activations zero-pad to the stored dim for any M, including
    the M<8 clamp; mismatched K still raises."""
    from repro.core.kneading import knead_padded

    w = jax.random.normal(jax.random.PRNGKey(3), (300, 100)) * 0.05
    kw = knead_padded(w, bits=8, ks=256)
    for m in (1, 7, 8):
        a = jnp.ones((m, 300))
        padded, m_out, bm_eff = _pad_activations(a, kw, 256)
        assert padded.shape[1] == kw.k and m_out == m and bm_eff == 8
    with pytest.raises(ValueError, match="neither"):
        _pad_activations(jnp.ones((1, 299)), kw, 256)


@pytest.mark.parametrize("adtype", [jnp.float32, jnp.bfloat16])
def test_sac_kernel_activation_dtypes(adtype):
    w, a = _wa(7, 8, 256, 128, dtype=adtype)
    kw = knead(w, bits=8, ks=256, n_block=128)
    ref = sac_matmul_ref(a.astype(jnp.float32), kw)
    out = sac_matmul_pallas(a, kw, bm=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_sac_kernel_occupancy_skipping_exact():
    """Zeroed high planes must not change the result (skipped, not wrong).

    The second K-block is ~100x smaller than the first; with per-channel
    scales set by the large block, its codes have empty high planes -> its
    (plane, K-tile) occupancy entries go to zero and the kernel skips them.
    """
    w, a = _wa(9, 8, 512, 128)
    w = w.at[256:].multiply(0.01)
    kw = knead(w, bits=16, ks=256, n_block=128)
    occ = np.asarray(kw.occupancy_map())
    assert occ.sum() < occ.size       # some tiles actually skip
    # the schedule dispatches exactly the occupied tiles, nothing more
    assert kw.schedule.total_work == int(occ.sum())
    assert kw.schedule.total_work < kw.schedule.dense_work(kw.bits)
    out = sac_matmul_pallas(a, kw, bm=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(sac_matmul_ref(a, kw)),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_kneaded_gemm_int8(m, k, n):
    w, a = _wa(m + k, m, k, n)
    qt = quantize(w, bits=8)
    scale = qt.scale.reshape(1, -1)
    ref = kneaded_gemm_ref(a, qt.q, scale)
    out = kneaded_gemm(a, qt.q, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_kneaded_gemm_int4_packed(m, k, n):
    w, a = _wa(m + k + 1, m, k, n)
    qt = quantize(w, bits=4)
    packed = pack_int4(qt.q)
    assert packed.shape == (k // 2, n)
    scale = qt.scale.reshape(1, -1)
    ref = kneaded_gemm_ref(a, packed, scale, packed4=True)
    out = kneaded_gemm(a, packed, scale, packed4=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_int4_pack_roundtrip():
    q = jnp.arange(-8, 8, dtype=jnp.int8).reshape(16, 1)
    q = jnp.tile(q, (2, 3))
    assert bool(jnp.array_equal(unpack_int4(pack_int4(q)), q))


def test_kernel_bytes_reduction():
    """The kneaded format's HBM footprint: bits/16 of bf16 + metadata."""
    w, _ = _wa(3, 1, 1024, 256)
    kw8 = knead(w, bits=8, ks=256)
    kw16 = knead(w, bits=16, ks=256)
    dense = kw8.dense_bf16_bytes()
    assert kw8.packed_bytes() < 0.75 * dense
    assert kw16.packed_bytes() < 1.5 * dense
    assert kw8.packed_bytes() < kw16.packed_bytes()
