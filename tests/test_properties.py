"""Deeper property-based tests on the paper's invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import bitplanes, knead, kneaded_cycles, quantize, sac_matmul
from repro.core.kneading import kneading_ratio
from repro.models import layers

settings.register_profile("ci2", deadline=None, max_examples=15)
settings.load_profile("ci2")


def _w(seed, shape, scale=0.05):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


# ----------------------------------------------------------------- kneading
@given(seed=st.integers(0, 20))
def test_kneading_ratio_monotone_in_ks(seed):
    """Fig 11's shape: more weights kneaded => fewer cycles per weight.

    max of sums grows sublinearly: E[max_b count_b(2K)] <= 2 E[max_b count_b(K)],
    so T_ks/T0 is (weakly) decreasing in KS on any weight distribution."""
    q = quantize(_w(seed, (192, 8)), bits=16, axis=None).q
    ratios = [float(kneading_ratio(q, 16, ks)) for ks in (8, 16, 32, 64)]
    for a, b in zip(ratios, ratios[1:]):
        assert b <= a + 1e-6


@given(seed=st.integers(0, 20), ks=st.sampled_from([8, 16]))
def test_kneaded_cycles_permutation_invariant_within_group(seed, ks):
    """Kneading counts bit columns — the order of weights inside a group
    cannot matter (the splitter references any activation in the KS range)."""
    q = quantize(_w(seed, (ks, 4)), bits=16, axis=None).q
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), ks)
    assert bool(jnp.array_equal(kneaded_cycles(q, 16, ks),
                                kneaded_cycles(q[perm], 16, ks)))


@given(seed=st.integers(0, 20))
def test_kneaded_cycles_subadditive_merge(seed):
    """Merging two groups can only help (or tie): the 2K-group cycle count
    is at most the sum of the two K-group counts."""
    q = quantize(_w(seed, (64, 4)), bits=16, axis=None).q
    c32 = kneaded_cycles(q, 16, 32)              # [2, 4]
    c64 = kneaded_cycles(q, 16, 64)              # [1, 4]
    assert bool(jnp.all(c64[0] <= c32[0] + c32[1]))


# ---------------------------------------------------------------- bit planes
@given(seed=st.integers(0, 30), bits=st.sampled_from([4, 8, 16]))
def test_plane_popcount_identity(seed, bits):
    """sum_b P_b == popcount(|q|): the planes carry exactly the essential
    bits the paper counts."""
    qmax = 2 ** (bits - 1) - 1
    q = jax.random.randint(jax.random.PRNGKey(seed), (63, 5), -qmax,
                           qmax + 1)
    planes = bitplanes.magnitude_planes(q, bits)
    assert bool(jnp.array_equal(
        jnp.sum(planes.astype(jnp.int32), axis=0),
        bitplanes.popcount(jnp.abs(q))))


@given(seed=st.integers(0, 20))
def test_occupancy_zero_iff_tile_empty(seed):
    planes = (jax.random.uniform(jax.random.PRNGKey(seed), (4, 64, 16))
              < 0.02).astype(jnp.int8)
    occ = bitplanes.plane_tile_occupancy(planes, 32, 8)
    t = planes.reshape(4, 2, 32, 2, 8)
    for b in range(4):
        for i in range(2):
            for j in range(2):
                empty = int(jnp.sum(t[b, i, :, j, :])) == 0
                assert bool(occ[b, i, j] == 0) == empty


# ----------------------------------------------------------------------- SAC
@given(seed=st.integers(0, 15))
def test_sac_matmul_linear_in_activations(seed):
    """SAC is exactly linear in A (Eq. 2 regroups a bilinear form)."""
    kw = knead(_w(seed, (128, 128)), bits=8, ks=32)
    a1 = _w(seed + 1, (4, 128), 1.0)
    a2 = _w(seed + 2, (4, 128), 1.0)
    lhs = sac_matmul(a1 + a2, kw, impl="int")
    rhs = sac_matmul(a1, kw, impl="int") + sac_matmul(a2, kw, impl="int")
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=2e-4)


@given(seed=st.integers(0, 15), bits=st.sampled_from([4, 8]))
def test_quantize_idempotent(seed, bits):
    w = _w(seed, (64, 8))
    q1 = quantize(w, bits=bits)
    w2 = q1.q * q1.scale
    q2 = quantize(w2, bits=bits, scale=q1.scale)
    assert bool(jnp.array_equal(q1.q, q2.q))


# ------------------------------------------------------------------ attention
@given(shift=st.integers(0, 64))
def test_rope_relative_position_property(shift):
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    def dot_at(i, j):
        qr = layers.apply_rope(q, jnp.array([[i]]), 1e4)
        kr = layers.apply_rope(k, jnp.array([[j]]), 1e4)
        return float(jnp.sum(qr[0, 0, 0, 0] * kr[0, 0, 0]))
    assert abs(dot_at(5, 3) - dot_at(5 + shift, 3 + shift)) < 1e-3


@given(seed=st.integers(0, 10))
def test_attention_rows_convex_combination(seed):
    """Attention outputs lie in the convex hull of V rows: componentwise
    min(V) <= out <= max(V) for each kv head."""
    q = _w(seed, (1, 16, 1, 2, 8), 1.0)
    k = _w(seed + 1, (1, 16, 1, 8), 1.0)
    v = _w(seed + 2, (1, 16, 1, 8), 1.0)
    out = layers.full_attention(q, k, v, causal=False).astype(jnp.float32)
    lo = jnp.min(v, axis=1)[:, None, :, None, :] - 1e-4
    hi = jnp.max(v, axis=1)[:, None, :, None, :] + 1e-4
    assert bool(jnp.all(out >= lo)) and bool(jnp.all(out <= hi))


@given(seed=st.integers(0, 8), chunk=st.sampled_from([16, 32, 64]))
def test_flash_chunk_size_invariance(seed, chunk):
    """The blockwise decomposition is exact for every chunk size."""
    q = _w(seed, (1, 128, 1, 2, 16), 1.0)
    k = _w(seed + 1, (1, 128, 1, 16), 1.0)
    v = _w(seed + 2, (1, 128, 1, 16), 1.0)
    ref = layers.full_attention(q, k, v, causal=True)
    out = layers.flash_attention(q, k, v, True, chunk, 0)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=1e-5)
