"""Kneaded CNN inference path: Pallas parity, occupancy skipping, engine.

The SAC planes oracle accumulates K tiles in the kernel's grid order, so
"pallas" (interpret mode) vs "planes" is asserted *bit-exact*, not close —
any divergence in unpack/sign/epilogue logic fails loudly.  The end-to-end
engine tests pin the acceptance criterion: a CNN forward runs fully kneaded
through every impl, matching the float model within quantization error.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import parity
import pytest

from repro.core import dequantize, quantize
from repro.core.kneading import knead, knead_padded, kneadable_dims
from repro.core.sac import sac_matmul
from repro.inference.cnn_engine import CNNServingConfig, CNNServingEngine
from repro.kernels.sac_matmul.ops import sac_conv2d, sac_matmul_pallas
from repro.models import cnn


def _wa(seed, m, k, n):
    kk = jax.random.split(jax.random.PRNGKey(seed), 2)
    return (jax.random.normal(kk[0], (k, n)) * 0.05,
            jax.random.normal(kk[1], (m, k)))


# ---------------------------------------- pallas parity (shared harness)

# non-square M/K/N, K spanning one and multiple kernel tiles
PARITY_SHAPES = [(24, 512, 128), (8, 1024, 256), (40, 768, 128)]


@pytest.mark.parametrize("m,k,n", PARITY_SHAPES)
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("ks", [256, 512])
def test_pallas_matches_planes_bit_exact(m, k, n, bits, ks):
    if k % ks:
        pytest.skip(f"K={k} not divisible by ks={ks}")
    parity.run_case(bits + ks + m, m, k, n, bits=bits, ks=ks)


@pytest.mark.parametrize("k0,n0", [(300, 100), (27, 64), (4800, 192)])
def test_pallas_parity_padded_dims(k0, n0):
    """Arbitrary (im2col-like) dims through knead_padded: the full impl
    agreement matrix holds on the logical region and the padded dims are
    tracked."""
    a, w, kw = parity.knead_case(k0, 8, k0, n0)
    assert (kw.k, kw.n) == kneadable_dims(k0, n0, 256, 128)
    assert (kw.logical_k, kw.logical_n) == (k0, n0)
    outs = parity.check_parity(a, w, kw)
    assert outs["pallas"].shape == (8, n0)


# padded/im2col-shaped sweep of the shared harness (hypothesis-gated)
test_cnn_impl_parity_sweep = parity.make_sweep_test(
    shapes=((8, 300, 100), (2, 27, 64), (8, 768, 192)))


def test_occupancy_zero_segment_untouched():
    """occupancy == 0 => the kernel never touches that (plane, tile) segment.

    Proof by falsification: drop one essential-bit-carrying plane from the
    occupancy map and rebuild the schedule (``with_occupancy`` — the kernel
    executes the *schedule*, so tampering must go through it).  If the kernel
    consulted the planes rather than the metadata, the output would be
    unchanged; because it dispatches scheduled items only, the output must
    drop exactly that plane's 2^b contribution — which the (metadata-
    oblivious) planes oracle reproduces only when fed the same plane zeroed
    out.
    """
    w, a = _wa(11, 8, 512, 128)
    kw = knead(w, bits=8, ks=256, n_block=128)
    occ = kw.occupancy_map()
    b = int(np.argmax(np.asarray(occ).sum(axis=(1, 2))))
    assert int(np.asarray(occ)[b].sum()) > 0

    kw_skip = kw.with_occupancy(occ.at[b].set(0))
    assert (kw_skip.schedule.total_work
            == kw.schedule.total_work - int(np.asarray(occ)[b].sum()))
    out_skip = sac_matmul_pallas(a, kw_skip, bm=8)

    planes0 = kw.planes.at[b].set(jnp.zeros_like(kw.planes[b]))
    kw_zero = dataclasses.replace(kw, planes=planes0)
    out_oracle = sac_matmul(a, kw_zero, impl="planes")

    full = sac_matmul(a, kw, impl="planes")
    assert float(jnp.max(jnp.abs(full - out_oracle))) > 0  # plane mattered
    np.testing.assert_array_equal(np.asarray(out_skip),
                                  np.asarray(out_oracle))


def test_sac_conv2d_matches_lax_conv():
    """sac_conv2d == the float convolution within quantization tolerance."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 10, 10, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (72, 32)) * 0.05
    kw = knead_padded(w, bits=8, ks=256)
    ref = cnn._im2col(x, 3, 1) @ w
    for impl in ("int", "planes", "pallas"):
        out = sac_conv2d(x, kw, ksize=3, stride=1, impl=impl)
        assert out.shape == ref.shape
        qerr = float(jnp.max(jnp.abs(dequantize(quantize(w, bits=8)) - w)))
        bound = qerr * 72 * float(jnp.max(jnp.abs(x))) + 1e-4
        assert float(jnp.max(jnp.abs(out - ref))) <= bound


def test_sac_conv2d_single_launch():
    """A conv layer is exactly ONE pallas_call — the grid's M dimension
    streams every activation row; there is no host-side slab loop — and the
    M-block size must not change the result."""
    from repro.kernels.sac_matmul import ops as sac_ops

    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 8, 3))
    w = jax.random.normal(jax.random.PRNGKey(3), (27, 64)) * 0.1
    kw = knead_padded(w, bits=8, ks=256)

    calls = []
    real = sac_ops.sac_matmul_pallas

    def counting(*args, **kwargs):
        calls.append(1)
        return real(*args, **kwargs)

    sac_ops.sac_matmul_pallas = counting
    try:
        # M = 2*8*8 = 128 rows: multiple bm=32 M-steps, still one launch
        full = sac_conv2d(x, kw, ksize=3, impl="pallas", bm=256)
        blocked = sac_conv2d(x, kw, ksize=3, impl="pallas", bm=32)
    finally:
        sac_ops.sac_matmul_pallas = real
    assert len(calls) == 2          # one kernel dispatch per sac_conv2d call
    np.testing.assert_array_equal(np.asarray(full), np.asarray(blocked))


# -------------------------------------------------------- end-to-end engine

def _small_cfg(name):
    return dataclasses.replace(cnn.CNN_ZOO[name], image_size=16)


@pytest.mark.parametrize("name", ["alexnet", "nin"])
def test_kneaded_cnn_close_to_float(name):
    """KneadedCNN logits vs float CNN within the quantization error bound."""
    cfg = _small_cfg(name)
    params = cnn.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    ref = CNNServingEngine(cfg, params, CNNServingConfig(impl="float")).logits(x)
    out = CNNServingEngine(cfg, params, CNNServingConfig(impl="int")).logits(x)
    assert out.shape == ref.shape
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    # int8 per-channel quantization of every layer: relative logit error
    # stays well under 10% for these depths (empirically ~1-3%)
    assert float(jnp.max(jnp.abs(out - ref))) / scale < 0.1
    agree = float(jnp.mean((jnp.argmax(out, -1) == jnp.argmax(ref, -1))
                           .astype(jnp.float32)))
    assert agree == 1.0


def test_kneaded_cnn_pallas_bit_exact_vs_planes():
    """AlexNet@16 runs FULLY kneaded through the Pallas kernel; logits are
    bit-exact against the planes oracle (the acceptance criterion)."""
    cfg = _small_cfg("alexnet")
    params = cnn.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    scfg = dict(bits=8, ks=256, jit=False)
    lp = CNNServingEngine(cfg, params,
                          CNNServingConfig(impl="planes", **scfg)).logits(x)
    lg = CNNServingEngine(cfg, params,
                          CNNServingConfig(impl="pallas", **scfg)).logits(x)
    np.testing.assert_array_equal(np.asarray(lg), np.asarray(lp))


def test_engine_classify_and_bytes():
    cfg = _small_cfg("nin")
    params = cnn.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16, 16, 3))
    eng = CNNServingEngine(cfg, params, CNNServingConfig(impl="int"))
    pred = eng.classify(x)
    assert pred.shape == (3,) and pred.dtype == jnp.int32
    dense = sum(leaf.size * 2 for leaf in jax.tree.leaves(params))
    # int8 planes are bits/16 of bf16 per stored element, but NiN's small
    # conv reduction dims (27, 75) pay real lcm(32, ks) alignment padding,
    # so the end-to-end ratio lands near 0.77 rather than 0.5
    assert eng.serving_bytes() < 0.85 * dense
    report = eng.layer_report()
    assert len(report) == len(params)
    for row in report:
        assert 0.0 < row["cycle_ratio"] <= 1.0
        assert row["bytes_vs_bf16"] < 0.75
