"""Shared cross-impl parity harness (not collected — no ``test_`` prefix).

One place owns the "all four SAC execution paths agree" check that
``test_kernels``, ``test_cnn_kneaded``, and ``test_lm_kneaded`` previously
each hand-rolled: build a (sparse) weight, knead it, run every impl of
``repro.core.sac.sac_matmul``, and assert the agreement matrix

  * ``pallas == planes``  bit-exact (the kernel replays the compacted
    schedule's accumulation order — any unpack/sign/epilogue drift fails)
  * ``float == int``      bit-exact (identical math: one f32 matmul against
    the dequantized codes)
  * ``int ~= planes``     f32-matmul tolerance (same values, different
    accumulation order)
  * ``int ~= a @ dequantize(quantize(w))``  the quantized-model reference

``make_sweep_test`` stamps out the hypothesis-gated sweep over
shapes x sparsities x bits (gated like test_schedule.py: skips with a clear
reason when hypothesis is absent); each consumer binds one with its own
shape pool (kernel tiles, padded im2col dims, LM projections).
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import dequantize, quantize
from repro.core.kneading import knead, knead_padded
from repro.core.sac import SAC_IMPLS, sac_matmul

settings.register_profile("parity", deadline=None, max_examples=12)
settings.load_profile("parity")

# default sweep pools: M spans the GEMV/decode regime (1, 7) through the
# streamed-grid regime; K one and multiple kernel tiles; N one and two tiles
SWEEP_SHAPES = ((1, 256, 128), (7, 256, 128), (8, 512, 128), (24, 512, 256))
SWEEP_BITS = (4, 8)
SWEEP_SPARSITIES = (0.0, 0.7, 0.95)


def sparse_weight(seed: int, k: int, n: int, sparsity: float = 0.0,
                  scale: float = 0.05) -> jax.Array:
    """A random [K, N] weight with element sparsity (0.0 = dense)."""
    kk = jax.random.split(jax.random.PRNGKey(seed), 2)
    w = jax.random.normal(kk[0], (k, n)) * scale
    if sparsity > 0:
        keep = jax.random.uniform(kk[1], (k, n)) >= sparsity
        w = w * keep
    return w


def knead_case(seed: int, m: int, k: int, n: int, *, bits: int = 8,
               ks: int = 256, n_block: int = 128, sparsity: float = 0.0):
    """(activations [M, K], float weight [K, N], kneaded weight).

    Uses :func:`knead` for tile-aligned dims and :func:`knead_padded`
    otherwise, so arbitrary (im2col / LM head) dims flow through the same
    case builder.
    """
    w = sparse_weight(seed, k, n, sparsity)
    a = jax.random.normal(jax.random.PRNGKey(seed + 9973), (m, k))
    aligned = (k % np.lcm(32, ks) == 0) and (n % n_block == 0)
    kneader = knead if aligned else knead_padded
    return a, w, kneader(w, bits=bits, ks=ks, n_block=n_block)


def check_parity(a: jax.Array, w: jax.Array, kw, *, rtol: float = 1e-5,
                 atol: float = 1e-4) -> dict:
    """Assert the full impl agreement matrix; returns the per-impl outputs."""
    outs = {impl: np.asarray(sac_matmul(a, kw, impl=impl))
            for impl in SAC_IMPLS}
    np.testing.assert_array_equal(outs["pallas"], outs["planes"])
    np.testing.assert_array_equal(outs["float"], outs["int"])
    np.testing.assert_allclose(outs["int"], outs["planes"],
                               rtol=rtol, atol=atol)
    ref = np.asarray(
        a.astype(jnp.float32) @ dequantize(quantize(w, bits=kw.bits,
                                                    axis=-1)))
    np.testing.assert_allclose(outs["int"], ref, rtol=rtol, atol=atol)
    return outs


def check_skip_parity(a: jax.Array, kw, *, impls=("planes", "pallas")) -> dict:
    """Activation-skip agreement (docs/DESIGN.md §12): for each impl,
    ``skip_activations=True`` must be BIT-IDENTICAL to skip-off — the
    runtime mask only drops work items whose contribution is exactly 0.
    Returns the skip-on outputs (all also asserted equal to each other)."""
    outs = {}
    for impl in impls:
        on = np.asarray(sac_matmul(a, kw, impl=impl, skip_activations=True))
        off = np.asarray(sac_matmul(a, kw, impl=impl))
        np.testing.assert_array_equal(on, off)
        outs[impl] = on
    vals = list(outs.values())
    for other in vals[1:]:
        np.testing.assert_array_equal(vals[0], other)
    return outs


def run_case(seed: int, m: int, k: int, n: int, *, bits: int = 8,
             ks: int = 256, n_block: int = 128,
             sparsity: float = 0.0) -> dict:
    """Build a case and check it — the one-call form the sweeps use."""
    a, w, kw = knead_case(seed, m, k, n, bits=bits, ks=ks, n_block=n_block,
                          sparsity=sparsity)
    return check_parity(a, w, kw)


def make_sweep_test(shapes=SWEEP_SHAPES, bits=SWEEP_BITS,
                    sparsities=SWEEP_SPARSITIES, ks: int = 256,
                    n_block: int = 128):
    """A hypothesis-gated parity sweep over shapes x sparsities x bits.

    Bind the return value to a ``test_*`` name in a test module; when
    hypothesis is unavailable it collects as a skip with the install hint.
    """
    @given(seed=st.integers(0, 10), shape=st.sampled_from(list(shapes)),
           b=st.sampled_from(list(bits)),
           sparsity=st.sampled_from(list(sparsities)))
    def sweep(seed, shape, b, sparsity):
        m, k, n = shape
        run_case(seed, m, k, n, bits=b, ks=ks, n_block=n_block,
                 sparsity=sparsity)

    return sweep
