"""Serving-engine and long-context behaviour tests."""
import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.inference.engine import ServingConfig, ServingEngine
from repro.models.lm import LanguageModel


def test_generate_shapes_and_determinism():
    cfg = get_config("llama3-8b", smoke=True)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, ServingConfig(max_len=64))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 10), 0,
                                 cfg.vocab_size)
    a = eng.generate({"tokens": prompts}, 8)
    b = eng.generate({"tokens": prompts}, 8)
    assert a.shape == (3, 8)
    assert bool(jnp.array_equal(a, b))          # greedy is deterministic


def test_generate_temperature_sampling_varies():
    cfg = get_config("llama3-8b", smoke=True)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        ServingConfig(max_len=64, temperature=2.0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                 cfg.vocab_size)
    a = eng.generate({"tokens": prompts}, 12, key=jax.random.PRNGKey(1))
    b = eng.generate({"tokens": prompts}, 12, key=jax.random.PRNGKey(2))
    assert not bool(jnp.array_equal(a, b))      # different keys, hot samples


def test_windowed_attention_decode_consistency():
    """Sliding-window arch: decode must match full forward (the window mask
    applies identically in blockwise and decode paths)."""
    cfg = dataclasses.replace(get_config("llama3-8b", smoke=True), window=24)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 48
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, S + 1), 0,
                              cfg.vocab_size)
    full = model.logits(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :S]})
    cache = jax.tree.map(
        lambda x: jnp.pad(x, [(0, 0)] * (x.ndim - 3) + [(0, 16), (0, 0),
                                                        (0, 0)])
        if x.ndim >= 4 and x.shape[-3] == S else x, cache)
    dec, _ = model.decode_step(params, toks[:, S:S + 1],
                               jnp.full((2,), S, jnp.int32), cache)
    err = float(jnp.max(jnp.abs(dec.astype(jnp.float32)
                                - full[:, -1].astype(jnp.float32))))
    assert err / (float(jnp.max(jnp.abs(full[:, -1]))) + 1e-9) < 0.05


def test_long_context_decode_ssm_constant_state():
    """xlstm decode cache size is independent of context length (the
    long_500k feasibility argument)."""
    cfg = get_config("xlstm-1.3b", smoke=True)
    model = LanguageModel(cfg)
    small = model.cache_spec(batch=1, max_len=32)
    huge = model.cache_spec(batch=1, max_len=524_288)
    b_small = sum(np.prod(l.shape) for l in jax.tree.leaves(small))
    b_huge = sum(np.prod(l.shape) for l in jax.tree.leaves(huge))
    assert b_small == b_huge


def test_elastic_restore_to_mesh_subprocess(tmp_path):
    """Save on 1 device; restore re-sharded onto an 8-device mesh."""
    import jax.numpy as jnp
    from repro.checkpoint import checkpointer as ckpt
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": jnp.ones((16,), jnp.bfloat16)}
    ckpt.save(tmp_path, 3, tree)
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, json
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import checkpointer as ckpt
        mesh = jax.make_mesh((8,), ("data",))
        like = {{"w": jnp.zeros((8, 8), jnp.float32),
                 "b": jnp.zeros((16,), jnp.bfloat16)}}
        sh = {{"w": NamedSharding(mesh, P("data", None)),
              "b": NamedSharding(mesh, P("data"))}}
        tree = ckpt.restore({str(tmp_path)!r}, 3, like, shardings=sh)
        ok = bool(jnp.array_equal(
            tree["w"], jnp.arange(64, dtype=jnp.float32).reshape(8, 8)))
        n_shards = len(tree["w"].sharding.device_set)
        print(json.dumps({{"ok": ok, "n_shards": n_shards}}))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={"PYTHONPATH": "src",
                                         "PATH": "/usr/bin:/bin"},
                         timeout=600)
    assert out.returncode == 0, out.stderr[-1500:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["ok"] and r["n_shards"] == 8


@pytest.mark.slow
def test_pipeline_compiles_on_512_multipod():
    """GPipe over the pod axis lowers+compiles on the production 2x16x16
    mesh (the PP entry of the dry-run deliverable)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax, json
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_production_mesh
        from repro.runtime.pipeline import pipeline_apply

        mesh = make_production_mesh(multi_pod=True)
        L, M, mb, S, D = 8, 4, 8, 512, 1024

        def layer(p, h):
            return jnp.tanh(h @ p)

        def step(w, x):
            return pipeline_apply(layer, w, x, mesh, stage_axis="pod")

        w = jax.ShapeDtypeStruct((L, D, D), jnp.float32,
                                 sharding=NamedSharding(mesh, P("pod")))
        x = jax.ShapeDtypeStruct((M, mb, S, D), jnp.float32,
                                 sharding=NamedSharding(mesh, P()))
        compiled = jax.jit(step).lower(w, x).compile()
        txt = compiled.as_text()
        print(json.dumps({"ok": True,
                          "has_ppermute": "collective-permute" in txt}))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={"PYTHONPATH": "src",
                                         "PATH": "/usr/bin:/bin"},
                         timeout=600)
    assert out.returncode == 0, out.stderr[-1500:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["ok"] and r["has_ppermute"]


def test_int8_kv_cache_decode_consistency():
    """kv_cache_bits=8 (kneaded KV cache): decode logits within int8
    tolerance of the full forward; cache stored as int8 codes + scales."""
    cfg = dataclasses.replace(get_config("llama3-8b", smoke=True),
                              kv_cache_bits=8)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S + 1), 0,
                              cfg.vocab_size)
    full = model.logits(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :S]})
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache

    def pad(x):
        if x.ndim >= 4 and x.shape[-3] == S:
            p = [(0, 0)] * x.ndim
            p[-3] = (0, 16)
            return jnp.pad(x, p)
        if x.ndim >= 3 and x.shape[-2] == S and x.dtype == jnp.float32:
            p = [(0, 0)] * x.ndim
            p[-2] = (0, 16)
            return jnp.pad(x, p, constant_values=1.0)
        return x
    cache = jax.tree.map(pad, cache)
    dec, cache2 = model.decode_step(params, toks[:, S:S + 1],
                                    jnp.full((2,), S, jnp.int32), cache)
    assert cache2["k"].dtype == jnp.int8
    err = float(jnp.max(jnp.abs(dec.astype(jnp.float32)
                                - full[:, -1].astype(jnp.float32))))
    assert err / (float(jnp.max(jnp.abs(full[:, -1]))) + 1e-9) < 0.1


def test_int8_kv_cache_bytes_halved():
    cfg8 = dataclasses.replace(get_config("llama3-8b", smoke=True),
                               kv_cache_bits=8)
    cfg = get_config("llama3-8b", smoke=True)
    b8 = sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
             for l in jax.tree.leaves(
                 LanguageModel(cfg8).cache_spec(4, 1024)))
    bf = sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
             for l in jax.tree.leaves(
                 LanguageModel(cfg).cache_spec(4, 1024)))
    # smoke hd=16: ratio = (hd + 4 scale bytes) / 2hd = 0.625;
    # at production hd=128 the ratio is 0.52
    assert b8 < 0.65 * bf
