"""Kneaded LM serving (decode-GEMV path): parity, cache round-trip, engine.

The transformer serving stack runs every ``_KNEADABLE`` projection through
the kneaded bit-plane form: stacked [L, K, N] scan-layer weights kneaded per
layer with a leading schedule axis (``knead_stacked``), dispatched by
``cfg.impl`` through ``sac_matmul`` — impl="pallas" being the
schedule-compacted kernel's decode-GEMV fast path.  "planes" replays the
same accumulation order, so whole-model prefill logits, decode-step logits,
and 32-token greedy generations are asserted BIT-EXACT between the two
(the acceptance criterion), with the float model as the quantization-error
reference.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import parity
import pytest

from repro.configs.registry import get_config
from repro.core.kneading import KneadedWeight, knead_padded, knead_stacked
from repro.inference.engine import ServingConfig, ServingEngine, knead_params
from repro.models.lm import LanguageModel

MIN_DIM = 8      # smoke dims are tiny; knead every projection


@pytest.fixture(scope="module")
def smol():
    """smollm-360m smoke arch + float params + kneaded params."""
    cfg = get_config("smollm-360m", smoke=True)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kparams = knead_params(params, bits=8, min_dim=MIN_DIM, kneaded=True)
    return cfg, model, params, kparams


def _model(cfg, impl):
    return LanguageModel(dataclasses.replace(cfg, impl=impl))


def _pad_cache(cache, cur, to):
    def pad(x):
        if x.ndim >= 4 and x.shape[-3] == cur:
            p = [(0, 0)] * x.ndim
            p[-3] = (0, to - cur)
            return jnp.pad(x, p)
        return x
    return jax.tree.map(pad, cache)


# --------------------------------------------------- stacked kneading form

def test_knead_params_stacks_scan_layers(smol):
    """Every attention/MLP projection leaf becomes a KneadedWeight whose
    arrays carry a leading num_layers axis (the scan slice axis)."""
    cfg, _, params, kparams = smol
    layers = kparams["layers"]
    for block, names in (("attn", ("wq", "wk", "wv", "wo")),
                         ("mlp", ("wi_gate", "wi_up", "wo"))):
        for name in names:
            kw = layers[block][name]
            orig = params["layers"][block][name]
            assert isinstance(kw, KneadedWeight), (block, name)
            L = cfg.num_layers
            assert kw.planes.shape[0] == L
            assert kw.signs.shape[0] == L
            assert kw.schedule.counts.shape[0] == L
            assert kw.schedule.plane_ids.shape == (
                L, kw.schedule.n_tiles, kw.schedule.num_work)
            assert (kw.logical_k, kw.logical_n) == orig.shape[-2:]
    # embeddings/norms stay float (tied smollm has no unembed leaf)
    assert not isinstance(kparams["embed"], KneadedWeight)


def test_stacked_layer_schedules_independent(smol):
    """The stacked kneading invariant: layer l's planes/signs/scale and
    compacted schedule equal exactly ``knead_padded(w[l])``'s — per-layer
    schedules are built independently, and the work-dim padding to the
    cross-layer max repeats each tile's last item."""
    cfg, _, params, _ = smol
    w = params["layers"]["attn"]["wq"]             # [L, K, N]
    stacked = knead_stacked(w, bits=8)
    for layer in range(cfg.num_layers):
        solo = knead_padded(w[layer], bits=8)
        np.testing.assert_array_equal(np.asarray(stacked.planes[layer]),
                                      np.asarray(solo.planes))
        np.testing.assert_array_equal(np.asarray(stacked.signs[layer]),
                                      np.asarray(solo.signs))
        np.testing.assert_array_equal(np.asarray(stacked.scale[layer]),
                                      np.asarray(solo.scale))
        np.testing.assert_array_equal(
            np.asarray(stacked.schedule.counts[layer]),
            np.asarray(solo.schedule.counts))
        W = solo.schedule.num_work
        np.testing.assert_array_equal(
            np.asarray(stacked.schedule.plane_ids[layer, :, :W]),
            np.asarray(solo.schedule.plane_ids))
        np.testing.assert_array_equal(
            np.asarray(stacked.schedule.ktile_ids[layer, :, :W]),
            np.asarray(solo.schedule.ktile_ids))
        # padding columns repeat the last item of each tile's list
        pid = np.asarray(stacked.schedule.plane_ids[layer])
        kid = np.asarray(stacked.schedule.ktile_ids[layer])
        assert (pid[:, W:] == pid[:, W - 1:W]).all()
        assert (kid[:, W:] == kid[:, W - 1:W]).all()
    assert stacked.schedule.num_work == max(
        knead_padded(w[i], bits=8).schedule.num_work
        for i in range(cfg.num_layers))
    assert stacked.schedule.total_work == sum(
        knead_padded(w[i], bits=8).schedule.total_work
        for i in range(cfg.num_layers))


# LM projection-shaped sweep of the shared harness (hypothesis-gated)
test_lm_impl_parity_sweep = parity.make_sweep_test(
    shapes=((1, 960, 960), (1, 960, 2560), (7, 2560, 960)), bits=(8,),
    sparsities=(0.0, 0.9))


# --------------------------------------------------------- model parity

def test_prefill_and_decode_step_parity(smol):
    """One decode step through the whole kneaded model: pallas bit-exact vs
    the planes oracle, and within quantization error of the float model."""
    cfg, model, params, kparams = smol
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    lp, cache_p = jax.jit(_model(cfg, "planes").prefill)(kparams, batch)
    lg, cache_g = jax.jit(_model(cfg, "pallas").prefill)(kparams, batch)
    np.testing.assert_array_equal(np.asarray(lp), np.asarray(lg))
    for a, b in zip(jax.tree.leaves(cache_p), jax.tree.leaves(cache_g)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    pos = jnp.full((2,), 8, jnp.int32)
    dp, _ = jax.jit(_model(cfg, "planes").decode_step)(
        kparams, toks[:, :1], pos, _pad_cache(cache_p, 8, 16))
    dg, _ = jax.jit(_model(cfg, "pallas").decode_step)(
        kparams, toks[:, :1], pos, _pad_cache(cache_g, 8, 16))
    np.testing.assert_array_equal(np.asarray(dp), np.asarray(dg))

    # float reference: int8 kneading drifts logits only within quant error
    lf = model.logits(params, batch)[:, -1].astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(lp.astype(jnp.float32) - lf))
                / (jnp.max(jnp.abs(lf)) + 1e-9))
    assert rel < 0.12


def test_prefill_decode_cache_roundtrip(smol):
    """Prefill -> padded cache -> decode must agree with the full forward
    at the decoded position (the KV cache round-trip), on the kneaded
    pallas path."""
    cfg, _, _, kparams = smol
    model = _model(cfg, "pallas")
    S = 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, S + 1), 0,
                              cfg.vocab_size)
    full = jax.jit(model.logits)(kparams, {"tokens": toks})
    _, cache = jax.jit(model.prefill)(kparams, {"tokens": toks[:, :S]})
    dec, cache2 = jax.jit(model.decode_step)(
        kparams, toks[:, S:S + 1], jnp.full((2,), S, jnp.int32),
        _pad_cache(cache, S, S + 4))
    ref = full[:, -1].astype(jnp.float32)
    err = float(jnp.max(jnp.abs(dec.astype(jnp.float32) - ref)))
    assert err / (float(jnp.max(jnp.abs(ref))) + 1e-9) < 0.05
    # the round trip extends the cache in place: seq extent is preserved
    assert cache2["k"].shape == _pad_cache(cache, S, S + 4)["k"].shape


# ------------------------------------------------------------- engine e2e

def test_serving_engine_pallas_bit_exact_vs_planes(smol):
    """Acceptance: ServingEngine greedy decode with impl="pallas" on
    smollm-360m (smoke dims) is bit-exact against the planes oracle for
    >= 32 tokens."""
    cfg, _, params, _ = smol
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                              cfg.vocab_size)
    gens = {}
    for impl in ("planes", "pallas"):
        eng = ServingEngine(cfg, params,
                            ServingConfig(max_len=48, impl=impl,
                                          knead_min_dim=MIN_DIM))
        gens[impl] = eng.generate({"tokens": toks}, 32)
    assert gens["pallas"].shape == (2, 32)
    np.testing.assert_array_equal(np.asarray(gens["pallas"]),
                                  np.asarray(gens["planes"]))


@pytest.mark.parametrize("impl", ["planes", "pallas"])
def test_serving_engine_activation_skip_bit_exact(smol, impl):
    """Acceptance (docs/DESIGN.md §12): 32-token greedy decode with
    ``activation_skip=True`` is BIT-IDENTICAL to skip-off on both the
    planes oracle and the pallas kernel — the runtime activation-occupancy
    intersection only drops tile-dots whose contribution is exactly 0 and
    preserves the k-major accumulation order of the survivors."""
    from repro.core import activation_occupancy

    cfg, _, params, _ = smol
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                              cfg.vocab_size)
    gens = {}
    activation_occupancy.reset_skip_stats()
    for skip in (False, True):
        eng = ServingEngine(cfg, params,
                            ServingConfig(max_len=48, impl=impl,
                                          knead_min_dim=MIN_DIM,
                                          activation_skip=skip))
        gens[skip] = eng.generate({"tokens": toks}, 32)
        if skip and impl == "pallas":
            # skip stats surface through the request front end
            stats = eng.latency_stats()
            if "act_skip_frac" in stats:
                assert 0.0 <= stats["act_skip_frac"] <= 1.0
                assert (stats["executed_tile_dots"]
                        <= stats["weight_tile_dots"])
    assert gens[True].shape == (2, 32)
    np.testing.assert_array_equal(np.asarray(gens[True]),
                                  np.asarray(gens[False]))
    if impl == "pallas":
        # the masked kernel actually ran (decode-GEMV rows engage the gate)
        stats = activation_occupancy.skip_stats()
        assert stats["weight_tile_dots"] > 0
        assert stats["executed_tile_dots"] <= stats["weight_tile_dots"]


def test_serving_engine_kneaded_close_to_float(smol):
    """Kneaded greedy decode mostly matches bf16 greedy decode (int8
    quantization changes at most occasional argmax ties)."""
    cfg, _, params, _ = smol
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0,
                              cfg.vocab_size)
    eng_f = ServingEngine(cfg, params, ServingConfig(max_len=32))
    eng_k = ServingEngine(cfg, params,
                          ServingConfig(max_len=32, impl="pallas",
                                        knead_min_dim=MIN_DIM))
    g_f = eng_f.generate({"tokens": toks}, 16)
    g_k = eng_k.generate({"tokens": toks}, 16)
    agree = float(jnp.mean((g_f == g_k).astype(jnp.float32)))
    assert agree > 0.6


def test_serving_engine_ssm_family_kneaded_parity():
    """SSM-family projections (in_proj/up/down/w_in/w_out/...) dispatch
    through cfg.impl too — xlstm greedy decode is bit-exact planes vs
    pallas, so the impl switch cannot silently fall back to the default
    path for non-attention blocks."""
    cfg = get_config("xlstm-1.3b", smoke=True)
    params = LanguageModel(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0,
                              cfg.vocab_size)
    gens = {}
    for impl in ("planes", "pallas"):
        eng = ServingEngine(cfg, params,
                            ServingConfig(max_len=32, impl=impl,
                                          knead_min_dim=MIN_DIM))
        gens[impl] = eng.generate({"tokens": toks}, 8)
    np.testing.assert_array_equal(np.asarray(gens["pallas"]),
                                  np.asarray(gens["planes"]))


def test_serving_engine_impl_validation(smol):
    cfg, _, params, _ = smol
    with pytest.raises(ValueError, match="impl"):
        ServingEngine(cfg, params, ServingConfig(impl="mxu"))


# ------------------------------------- zamba2 hybrid decode regression

@pytest.fixture(scope="module")
def zamba():
    cfg = get_config("zamba2-2.7b", smoke=True)
    params = LanguageModel(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 8), 0,
                              cfg.vocab_size)
    return cfg, params, toks


@pytest.mark.parametrize("impl", ["float", "quant", "int", "planes",
                                  "pallas"])
def test_zamba2_hybrid_engine_decodes(zamba, impl):
    """Regression (ROADMAP seed bug): ServingEngine decode on the hybrid
    family must run on EVERY serving path.  The engine's cache pad used to
    sniff shapes — any >=4-dim cache whose -3 axis equalled the prompt
    length got padded to max_len, and the Mamba2 SSM state [L, B, H, p, n]
    collides whenever its head count equals the prompt length (smoke: both
    8), stretching the state's *head* axis and crashing ``ssm.ssd_step``.
    The pad is now keyed on the cache dict's names ("k"/"v"/"*_scale"
    only), so SSM/conv states pass through untouched."""
    cfg, params, toks = zamba
    scfg = ServingConfig(max_len=32, impl=impl, knead_min_dim=MIN_DIM,
                         quant_bits=8 if impl == "quant" else 0)
    eng = ServingEngine(cfg, params, scfg)
    out = eng.generate({"tokens": toks}, 4)
    assert out.shape == (2, 4)
    assert out.dtype == jnp.int32


def test_zamba2_hybrid_decode_pallas_matches_planes(zamba):
    """The hybrid family's kneaded decode is bit-exact pallas vs planes —
    the SSM in_proj/out_proj projections dispatch through the SAC paths
    just like attention does."""
    cfg, params, toks = zamba
    gens = {}
    for impl in ("planes", "pallas"):
        eng = ServingEngine(cfg, params,
                            ServingConfig(max_len=32, impl=impl,
                                          knead_min_dim=MIN_DIM))
        gens[impl] = eng.generate({"tokens": toks}, 8)
    np.testing.assert_array_equal(np.asarray(gens["pallas"]),
                                  np.asarray(gens["planes"]))


def test_pad_cache_leaves_ssm_state_heads_alone(zamba):
    """The head-count == prompt-length collision, pinned directly: after
    _pad_cache only the attention KV seq axes grow; conv/ssm states keep
    their shapes bit-for-bit."""
    cfg, params, toks = zamba
    eng = ServingEngine(cfg, params, ServingConfig(max_len=32))
    logits, cache = eng._prefill(eng.params, {"tokens": toks})
    padded = eng._pad_cache(cache, toks.shape[1])
    assert padded["k"].shape[-3] == 32
    assert padded["v"].shape[-3] == 32
    np.testing.assert_array_equal(np.asarray(padded["conv"]),
                                  np.asarray(cache["conv"]))
    np.testing.assert_array_equal(np.asarray(padded["ssm"]),
                                  np.asarray(cache["ssm"]))


# ------------------------------------- batched request front end (LM)

def test_engine_submit_drain_matches_batch_generate(smol):
    """drain() serves queued prompts in padding-bucket micro-batches whose
    outputs equal generate() on the same padded batch bitwise (same shape
    -> same XLA program -> identical greedy argmax)."""
    cfg, _, params, _ = smol
    eng = ServingEngine(cfg, params,
                        ServingConfig(max_len=32, impl="pallas",
                                      knead_min_dim=MIN_DIM, buckets=(4,)))
    toks = jax.random.randint(jax.random.PRNGKey(7), (3, 8), 0,
                              cfg.vocab_size)
    ids = [eng.submit(toks[i], num_tokens=6) for i in range(3)]
    res = eng.drain()
    assert sorted(res) == sorted(ids)
    ref = eng.generate(
        {"tokens": jnp.pad(toks, ((0, 1), (0, 0)))}, 6)
    for i, rid in enumerate(ids):
        np.testing.assert_array_equal(np.asarray(res[rid]),
                                      np.asarray(ref[i]))
    stats = eng.latency_stats()
    assert stats["requests"] == 3
    assert stats["p95_ms"] >= stats["p50_ms"] > 0
    assert stats["mean_batch_fill"] == pytest.approx(0.75)
    assert eng.drain() == {}                 # queue fully drained


def test_engine_drain_groups_by_prompt_length(smol):
    """Mixed prompt lengths drain in per-length micro-batches (positions
    stay exact — no prompt padding), each bitwise-equal to generate() at
    its own padded shape; per-request token budgets are honored."""
    cfg, _, params, _ = smol
    eng = ServingEngine(cfg, params,
                        ServingConfig(max_len=32, impl="int",
                                      knead_min_dim=MIN_DIM, buckets=(2,)))
    short = jax.random.randint(jax.random.PRNGKey(8), (2, 4), 0,
                               cfg.vocab_size)
    long = jax.random.randint(jax.random.PRNGKey(9), (1, 10), 0,
                              cfg.vocab_size)
    rid_s = [eng.submit(short[i], num_tokens=5) for i in range(2)]
    rid_l = eng.submit(long[0], num_tokens=3)
    res = eng.drain()
    ref_s = eng.generate({"tokens": short}, 5)
    ref_l = eng.generate({"tokens": jnp.pad(long, ((0, 1), (0, 0)))}, 3)
    for i, rid in enumerate(rid_s):
        assert res[rid].shape == (5,)
        np.testing.assert_array_equal(np.asarray(res[rid]),
                                      np.asarray(ref_s[i]))
    assert res[rid_l].shape == (3,)
    np.testing.assert_array_equal(np.asarray(res[rid_l]),
                                  np.asarray(ref_l[0]))
    log = list(eng._request_log)
    assert sorted(r["prompt_len"] for r in log) == [4, 4, 10]


def test_engine_submit_validation(smol):
    cfg, _, params, _ = smol
    eng = ServingEngine(cfg, params, ServingConfig(max_len=16))
    with pytest.raises(ValueError, match="one prompt"):
        eng.submit(jnp.zeros((2, 8), jnp.int32))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(jnp.zeros((8,), jnp.int32), num_tokens=16)
    with pytest.raises(ValueError, match="buckets"):
        ServingEngine(cfg, params, ServingConfig(buckets=(4, 2)))
