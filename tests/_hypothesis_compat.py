"""Import-optional hypothesis shim.

The property-based tests depend on ``hypothesis``, which is pinned in
requirements-dev.txt but may be absent in minimal environments.  Importing
``given``/``settings``/``st`` from here instead of from hypothesis directly
keeps collection working everywhere: when hypothesis is missing, ``@given``
degrades to a pytest skip marker with a clear reason and the strategies
object returns inert placeholders.
"""
__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy constructor call and returns None."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None

            return strategy

    st = _StrategyStub()

    def given(*args, **kwargs):
        return pytest.mark.skip(
            reason="hypothesis not installed (property test; "
                   "pip install -r requirements-dev.txt)")

    class settings:  # noqa: N801 - mirrors the hypothesis API
        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass
