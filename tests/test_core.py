"""Core library tests: quantization, bit planes, kneading, SAC, cost model.

Property tests (hypothesis) pin the system invariants:
  * quantize/dequantize error bound  <= scale/2 per element
  * bit-plane decomposition is exact (int arithmetic)
  * knead -> unknead is bit-exact with dequantize(quantize(w))
  * SAC matmul == dense matmul on quantized weights (all impls)
  * kneaded cycles <= KS (never slower than DaDN) and >= essential rows
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    bitplanes, cost_model, knead, kneaded_cycles, kneading_ratio,
    quantize, dequantize, sac_matmul, sac_matmul_planes, unknead,
    weight_bit_stats,
)

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


def _rand(key, shape, scale=0.05):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------- quantize
@given(bits=st.integers(2, 16), seed=st.integers(0, 50))
def test_quantize_error_bound(bits, seed):
    w = _rand(seed, (64, 32))
    qt = quantize(w, bits=bits)
    err = jnp.abs(dequantize(qt) - w)
    bound = qt.scale / 2 + 1e-7
    assert bool(jnp.all(err <= jnp.broadcast_to(bound, err.shape)))


def test_quantize_zero_channel():
    w = jnp.zeros((32, 4))
    qt = quantize(w, bits=8)
    assert bool(jnp.all(qt.q == 0))
    assert bool(jnp.all(jnp.isfinite(qt.scale)))


# --------------------------------------------------------------- bitplanes
@given(bits=st.integers(2, 16), seed=st.integers(0, 50))
def test_bitplane_roundtrip(bits, seed):
    qmax = 2 ** (bits - 1) - 1
    q = jax.random.randint(jax.random.PRNGKey(seed), (37, 11), -qmax,
                           qmax + 1).astype(jnp.int32)
    planes = bitplanes.to_signed_planes(q, bits)
    assert bool(jnp.array_equal(bitplanes.from_signed_planes(planes), q))


@given(seed=st.integers(0, 50))
def test_pack_unpack_roundtrip(seed):
    bits01 = jax.random.bernoulli(
        jax.random.PRNGKey(seed), 0.4, (96, 17)).astype(jnp.uint8)
    packed = bitplanes.pack_bits(bits01, axis=0)
    assert packed.shape == (3, 17)
    assert bool(jnp.array_equal(bitplanes.unpack_bits(packed, axis=0), bits01))


def test_occupancy_exact():
    planes = jnp.zeros((3, 64, 8), jnp.int8).at[1, 5, 2].set(1)
    occ = bitplanes.plane_tile_occupancy(planes, 32, 8)
    assert occ.shape == (3, 2, 1)
    assert int(occ.sum()) == 1 and int(occ[1, 0, 0]) == 1


# ---------------------------------------------------------------- kneading
@given(bits=st.sampled_from([4, 8, 16]), seed=st.integers(0, 30))
def test_knead_unknead_exact(bits, seed):
    w = _rand(seed, (128, 128))
    qt = quantize(w, bits=bits)
    kw = knead(w, bits=bits, ks=32, n_block=128, qt=qt)
    assert bool(jnp.array_equal(unknead(kw), dequantize(qt)))


@given(ks=st.sampled_from([8, 16, 32]), seed=st.integers(0, 30))
def test_kneaded_cycles_bounds(ks, seed):
    w = _rand(seed, (128, 16))
    qt = quantize(w, bits=16)
    cyc = kneaded_cycles(qt.q, 16, ks)
    assert cyc.shape == (128 // ks, 16)
    assert bool(jnp.all(cyc <= ks))          # never slower than DaDN
    assert bool(jnp.all(cyc >= 0))
    ratio = kneading_ratio(qt.q, 16, ks)
    assert 0.0 <= float(ratio) <= 1.0


def test_kneading_zero_weights_free():
    """All-zero weights take zero cycles — the paper's zero-value claim."""
    q = jnp.zeros((64, 4), jnp.int16)
    assert int(jnp.sum(kneaded_cycles(q, 16, 16))) == 0


def test_kneading_fig3_example():
    """Paper Fig 3: the kneaded cycle count is the tallest bit column."""
    # 6 weights, 4-bit magnitudes: columns of the magnitude planes
    q = jnp.array([[0b0101, 0b0010, 0b0001, 0b1000, 0b0011, 0b0000]],
                  jnp.int16).T   # [6, 1]
    cyc = kneaded_cycles(q, bits=5, ks=6)
    # bit0: w0,w2,w4 -> 3;  bit1: w1,w4 -> 2;  bit2: w0 -> 1;  bit3: w3 -> 1
    assert int(cyc[0, 0]) == 3


# --------------------------------------------------------------------- SAC
@given(bits=st.sampled_from([4, 8, 16]), seed=st.integers(0, 20),
       m=st.sampled_from([1, 3, 8]))
def test_sac_matmul_all_impls_agree(bits, seed, m):
    w = _rand(seed, (128, 128))
    a = _rand(seed + 100, (m, 128), scale=1.0)
    qt = quantize(w, bits=bits)
    kw = knead(w, bits=bits, ks=32, qt=qt)
    ref = a @ dequantize(qt)
    for impl in ("planes", "int"):
        out = sac_matmul(a, kw, impl=impl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_sac_planes_is_shift_add():
    """The plane decomposition really is sum_b 2^b (A @ S_b)."""
    w = _rand(3, (64, 32))
    a = _rand(4, (2, 64), scale=1.0)
    qt = quantize(w, bits=8)
    kw = knead(w, bits=8, ks=32, n_block=32, qt=qt)
    out = sac_matmul_planes(a, kw)
    ref = a @ dequantize(qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------- cost model
def test_cost_model_tetris_faster_than_dadn():
    w = _rand(7, (256, 64))
    acts = jnp.abs(_rand(8, (256, 16), scale=1.0))
    qw = quantize(w, bits=16)
    qa = quantize(acts, bits=16)
    cb = cost_model.model_layer(qw.q, qa.q, bits=16, ks=16)
    sp = cb.speedup()
    assert sp["tetris"] > 1.0            # kneading always wins on slack
    assert cb.tetris <= cb.dadn


def test_cost_model_int8_doubles_throughput():
    w = _rand(9, (256, 64))
    acts = jnp.abs(_rand(10, (256, 16), scale=1.0))
    q16, q8 = quantize(w, bits=16), quantize(w, bits=8)
    qa = quantize(acts, bits=16)
    c16 = cost_model.model_layer(q16.q, qa.q, bits=16, ks=16, mode="fp16")
    c8 = cost_model.model_layer(q8.q, qa.q, bits=8, ks=16, mode="int8")
    assert c8.tetris < c16.tetris        # int8 mode is strictly faster


def test_edp_power_ratios():
    assert cost_model.edp(10.0, "pra") / cost_model.edp(10.0, "dadn") \
        == pytest.approx(3.37)


# ------------------------------------------------------------------- stats
def test_weight_bit_stats_ranges():
    s = weight_bit_stats(_rand(11, (512, 64)), bits=16)
    assert 0.0 <= s.zero_value_frac <= 1.0
    assert 0.3 <= s.zero_bit_frac <= 0.9      # gaussian weights ~50-60%
    assert s.per_bit_density.shape == (15,)
    # Fig 2 cliff: top magnitude bits are nearly empty
    assert s.per_bit_density[-1] < 0.2
