"""Kneaded expert-parallel MoE serving (docs/DESIGN.md §13).

Covers the expert-bank kneading form ([L, E, K, N] leaves kneaded
per-expert with independent schedules), the routed decode-GEMV path
(planes == pallas bit-exact through the whole qwen3-moe smoke engine),
expert parallelism over the dedicated "expert" mesh axis (EP ∈ {2, 4} and
the 2-D expert×model mesh bit-identical to a clean 1-device all-local
oracle subprocess), and the routing semantics the paths share:

* top_k tie-break order is pinned (``jax.lax.top_k`` keeps the LOWER
  expert index on equal probabilities) — routing must not depend on an
  unspecified sort,
* capacity overflow drops by global arrival order (capacity_factor < 1
  keeps the first ``cap`` routed tokens per expert, zeroes the rest),
* the Switch aux-loss value is pinned against an independent numpy
  recompute on a fixed seed,
* per-step routed/dropped counters surface through ``latency_stats()``
  and the static per-(layer, expert) work tables through
  ``expert_work_table()``.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.configs.registry import get_config
from repro.core import routing_stats
from repro.core.kneading import (KNEADABLE_NAMES, KneadedWeight,
                                 knead_padded, knead_stacked)
from repro.inference.engine import ServingConfig, ServingEngine, knead_params
from repro.models import blocks
from repro.models.lm import LanguageModel

MIN_DIM = 8      # smoke dims are tiny; knead every projection

MOE_ARCH = "qwen3-moe-30b-a3b"


@pytest.fixture(scope="module")
def moe():
    """qwen3-moe smoke arch + float params + kneaded params."""
    cfg = get_config(MOE_ARCH, smoke=True)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kparams = knead_params(params, bits=8, min_dim=MIN_DIM, kneaded=True)
    return cfg, model, params, kparams


# ------------------------------------------------------- expert-bank form

def test_knead_stacked_expert_bank_structure():
    """[L, E, K, N] kneads to a bank whose (l, e) slice equals the
    independent 2-D knead of w[l, e] exactly, with the work dim padded to
    the cross-slice max by repeating each tile's last item."""
    key = jax.random.split(jax.random.PRNGKey(3), 2)
    w = jax.random.normal(key[0], (2, 3, 96, 128)) * 0.05
    keep = jax.random.uniform(key[1], w.shape) >= 0.7
    w = w * keep
    bank = knead_stacked(w, bits=8)
    assert bank.planes.shape[:2] == (2, 3)
    assert bank.schedule.counts.shape[:2] == (2, 3)
    solos = [[knead_padded(w[l, e], bits=8) for e in range(3)]
             for l in range(2)]
    assert bank.schedule.num_work == max(
        s.schedule.num_work for row in solos for s in row)
    assert bank.schedule.total_work == sum(
        s.schedule.total_work for row in solos for s in row)
    for l in range(2):
        for e in range(3):
            solo = solos[l][e]
            np.testing.assert_array_equal(np.asarray(bank.planes[l, e]),
                                          np.asarray(solo.planes))
            np.testing.assert_array_equal(np.asarray(bank.signs[l, e]),
                                          np.asarray(solo.signs))
            np.testing.assert_array_equal(np.asarray(bank.scale[l, e]),
                                          np.asarray(solo.scale))
            np.testing.assert_array_equal(
                np.asarray(bank.schedule.counts[l, e]),
                np.asarray(solo.schedule.counts))
            width = solo.schedule.num_work
            np.testing.assert_array_equal(
                np.asarray(bank.schedule.plane_ids[l, e, :, :width]),
                np.asarray(solo.schedule.plane_ids))
            np.testing.assert_array_equal(
                np.asarray(bank.schedule.ktile_ids[l, e, :, :width]),
                np.asarray(solo.schedule.ktile_ids))
            pid = np.asarray(bank.schedule.plane_ids[l, e])
            assert (pid[:, width:] == pid[:, width - 1:width]).all()


def test_expert_bank_work_table():
    """work_table() sums each slice's compacted counts — a [L, E] static
    load map whose total equals the schedule's total_work."""
    w = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 64, 128)) * 0.05
    bank = knead_stacked(w, bits=8)
    table = bank.work_table()
    assert table.shape == (2, 4)
    assert table.sum() == bank.schedule.total_work
    for l in range(2):
        for e in range(4):
            solo = knead_padded(w[l, e], bits=8)
            assert table[l, e] == solo.schedule.total_work


def test_expert_bank_rejects_n_sharding():
    """Banks place on the 'expert' axis, never through the N-sharder."""
    w = jax.random.normal(jax.random.PRNGKey(5), (2, 2, 64, 128)) * 0.05
    bank = knead_stacked(w, bits=8)
    with pytest.raises(ValueError, match="expert"):
        bank.shard(None, "model")


def test_pallas_kernel_rejects_unsliced_bank():
    """The 2-D kernel entry refuses a stacked bank loudly instead of
    walking garbage."""
    from repro.kernels.sac_matmul.ops import sac_matmul_pallas
    w = jax.random.normal(jax.random.PRNGKey(6), (2, 2, 64, 128)) * 0.05
    bank = knead_stacked(w, bits=8)
    with pytest.raises(ValueError, match="stacked"):
        sac_matmul_pallas(jnp.ones((1, 64)), bank)


def test_kneadable_names_single_definition():
    """Satellite: the engine and the launch specs read the SAME tuple —
    the two serving paths cannot drift on what gets kneaded."""
    from repro.inference import engine
    from repro.launch import specs
    assert engine._KNEADABLE is KNEADABLE_NAMES
    assert specs._KNEADABLE is KNEADABLE_NAMES


def test_knead_params_warns_on_unkneaded_leaves(moe, caplog):
    """Kneadable-name leaves below min_dim are named in one warning
    instead of silently serving float."""
    _, _, params, _ = moe
    import logging
    with caplog.at_level(logging.WARNING, logger="repro.inference.engine"):
        knead_params(params, bits=8, min_dim=4096, kneaded=True)
    msgs = [r.getMessage() for r in caplog.records
            if "un-kneaded" in r.getMessage()]
    assert len(msgs) == 1
    assert "wq" in msgs[0] and "moe/wi" in msgs[0].replace("'", "")


def test_knead_params_builds_expert_banks(moe):
    """The >2-stack-dim exclusion is lifted: [L, E, K, N] MoE leaves
    become KneadedWeight banks with both stack axes in front."""
    cfg, _, params, kparams = moe
    for name in ("wi", "wo"):
        kw = kparams["layers"]["moe"][name]
        orig = params["layers"]["moe"][name]
        assert isinstance(kw, KneadedWeight), name
        assert kw.planes.shape[:2] == (cfg.num_layers, cfg.num_experts)
        assert kw.schedule.counts.shape[:2] == (cfg.num_layers,
                                                cfg.num_experts)
        assert (kw.logical_k, kw.logical_n) == orig.shape[-2:]
    # the router stays float: tiny and not a projection suffix
    assert not isinstance(kparams["layers"]["moe"]["router"], KneadedWeight)


# ------------------------------------------------------ routing semantics

def test_top_k_tie_break_prefers_lower_expert():
    """Pinned tie-break: equal router probabilities route to the LOWEST
    expert index, at every k — the decode trace is reproducible across
    runs and machines or this fails."""
    probs = jnp.full((1, 1, 6), 1.0 / 6.0)
    _, eids = jax.lax.top_k(probs, 3)
    np.testing.assert_array_equal(np.asarray(eids)[0, 0], [0, 1, 2])
    # partial tie under a strict maximum: order is still index-ascending
    probs = jnp.asarray([[[0.1, 0.3, 0.1, 0.3, 0.2, 0.0]]])
    _, eids = jax.lax.top_k(probs, 3)
    np.testing.assert_array_equal(np.asarray(eids)[0, 0], [1, 3, 4])


def test_capacity_overflow_drops_by_arrival_order():
    """capacity_factor < 1: each expert keeps its first ``cap`` routed
    tokens in arrival order; overflow tokens contribute exactly zero."""
    cfg = ModelConfig(name="tiny-moe", family="moe", num_experts=2,
                      top_k=1, moe_dff=16, d_model=8,
                      capacity_factor=0.5)
    t, d = 8, 8
    x2d = jnp.ones((t, d))
    eids = jnp.zeros((t, 1), jnp.int32)        # every token -> expert 0
    gates = jnp.ones((t, 1), jnp.float32)
    cap = blocks._capacity(t, cfg)
    assert cap < t
    xg, disp, slot_gate = blocks._route_slots(x2d, eids, gates, 2, 0, cap)
    # expert 0's slots hold tokens 0..cap-1 (arrival order), expert 1 none
    np.testing.assert_array_equal(np.asarray(disp[:cap]), np.arange(cap))
    assert (np.asarray(disp[cap:]) == t).all()           # pad-row gathers
    assert np.asarray(slot_gate[:cap]).sum() == cap
    assert np.asarray(slot_gate[cap:]).sum() == 0.0
    # the combine zeroes dropped tokens: scatter y == slot outputs back
    y = jnp.ones((2, cap, d))
    out = blocks._combine_slots(y, disp, slot_gate, t, jnp.float32)
    np.testing.assert_array_equal(np.asarray(out[:cap]), np.ones((cap, d)))
    np.testing.assert_array_equal(np.asarray(out[cap:]),
                                  np.zeros((t - cap, d)))


def test_router_aux_loss_pinned_on_fixed_seed(moe):
    """The Switch aux-loss value on a fixed seed equals an independent
    numpy recompute of E * sum(density * mean_prob) * coef."""
    cfg, model, params, _ = moe
    toks = jax.random.randint(jax.random.PRNGKey(11), (2, 8), 0,
                              cfg.vocab_size)
    x = jnp.take(params["embed"], toks, axis=0).astype(cfg.dtype)
    p0 = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
    _, aux = blocks.moe_apply(p0, x, cfg)

    from repro.models import layers as L
    from repro.models.layers import matmul_any
    h = L.apply_norm(p0["ln"], x, cfg.norm)
    logits = matmul_any(h, p0["router"], jnp.float32)
    probs = np.asarray(jax.nn.softmax(logits.astype(jnp.float32), axis=-1))
    _, eids = jax.lax.top_k(jnp.asarray(probs), cfg.top_k)
    eids = np.asarray(eids)
    density = np.stack([(eids == e).mean() for e in range(cfg.num_experts)])
    expected = (cfg.num_experts * (density * probs.mean((0, 1))).sum()
                * cfg.router_aux_coef)
    np.testing.assert_allclose(float(aux), expected, rtol=1e-4)
    assert float(aux) > 0.0


def test_moe_config_validation():
    with pytest.raises(ValueError, match="top_k"):
        ModelConfig(family="moe", num_experts=4, top_k=8, moe_dff=16)
    with pytest.raises(ValueError, match="moe_dff"):
        ModelConfig(family="moe", num_experts=4, top_k=2, moe_dff=0,
                    d_ff=0)
    with pytest.raises(ValueError, match="capacity_factor"):
        ModelConfig(family="moe", num_experts=4, top_k=2, moe_dff=16,
                    capacity_factor=0.0)


# ------------------------------------------------- kneaded decode parity

def test_moe_engine_pallas_bit_exact_vs_planes(moe):
    """ACCEPTANCE: kneaded-expert decode through the routed per-expert
    GEMV path is bit-exact planes == pallas on the qwen3-moe smoke
    engine, prefill logits and 32-token greedy generations."""
    cfg, _, params, _ = moe
    toks = jax.random.randint(jax.random.PRNGKey(12), (2, 8), 0,
                              cfg.vocab_size)
    outs = {}
    for impl in ("planes", "pallas"):
        eng = ServingEngine(cfg, params,
                            ServingConfig(max_len=48, impl=impl,
                                          knead_min_dim=MIN_DIM))
        with eng._mesh_ctx():
            logits, _ = eng._prefill(eng.params, {"tokens": toks})
        outs[impl] = (np.asarray(logits.astype(jnp.float32)),
                      np.asarray(eng.generate({"tokens": toks}, 32)))
    np.testing.assert_array_equal(outs["pallas"][0], outs["planes"][0])
    np.testing.assert_array_equal(outs["pallas"][1], outs["planes"][1])


def test_moe_engine_activation_skip_bit_exact(moe):
    """Two-sided skip on the routed per-expert GEMV calls (the PR-9 mask
    computed from exactly the routed rows) changes nothing bitwise."""
    cfg, _, params, _ = moe
    toks = jax.random.randint(jax.random.PRNGKey(13), (2, 8), 0,
                              cfg.vocab_size)
    gens = {}
    for skip in (False, True):
        eng = ServingEngine(cfg, params,
                            ServingConfig(max_len=48, impl="pallas",
                                          knead_min_dim=MIN_DIM,
                                          activation_skip=skip))
        gens[skip] = np.asarray(eng.generate({"tokens": toks}, 16))
    np.testing.assert_array_equal(gens[True], gens[False])


def test_moe_engine_quant_serves_dense_slab(moe):
    """The quantized (non-kneaded) MoE serving path is untouched: it still
    runs the capacity-padded dense slab and decodes."""
    cfg, _, params, _ = moe
    toks = jax.random.randint(jax.random.PRNGKey(14), (2, 8), 0,
                              cfg.vocab_size)
    eng = ServingEngine(cfg, params,
                        ServingConfig(max_len=32, impl="quant",
                                      quant_bits=8, knead_min_dim=MIN_DIM))
    out = eng.generate({"tokens": toks}, 8)
    assert out.shape == (2, 8)


# ------------------------------------------------- routing-load stats

def test_routing_stats_surface_through_latency_stats(moe):
    """Per-step routed-token and capacity-drop counters reach
    latency_stats(); the static work table is [L, E] per bank."""
    cfg, _, params, _ = moe
    routing_stats.reset_routing_stats()
    eng = ServingEngine(cfg, params,
                        ServingConfig(max_len=32, impl="pallas",
                                      knead_min_dim=MIN_DIM))
    eng.generate({"tokens": jnp.zeros((2, 8), jnp.int32)}, 4)
    stats = eng.latency_stats()
    assert stats["routing_steps"] > 0
    # every (token, k) routed pair lands somewhere: routed + dropped
    # accounts for batch * top_k per routed call
    assert stats["routed_tokens"] > 0
    assert stats["capacity_dropped"] >= 0
    tables = eng.expert_work_table()
    assert set(tables) == {"layers/moe/wi", "layers/moe/wo"}
    for table in tables.values():
        assert table.shape == (cfg.num_layers, cfg.num_experts)
        assert (table >= 0).all() and table.sum() > 0


def test_non_moe_engine_reports_no_routing_stats():
    cfg = get_config("smollm-360m", smoke=True)
    params = LanguageModel(cfg).init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        ServingConfig(max_len=32, impl="pallas",
                                      knead_min_dim=MIN_DIM))
    eng.generate({"tokens": jnp.zeros((2, 8), jnp.int32)}, 4)
    stats = eng.latency_stats()
    assert "routed_tokens" not in stats
    assert eng.expert_work_table() == {}


# ------------------------------------------- expert-parallel validation

def test_engine_expert_shards_validation(moe):
    cfg, _, params, _ = moe
    with pytest.raises(ValueError, match="does not knead"):
        ServingEngine(cfg, params, ServingConfig(expert_shards=2,
                                                 impl="quant"))
    with pytest.raises(ValueError, match="not divisible"):
        ServingEngine(cfg, params, ServingConfig(expert_shards=3,
                                                 impl="pallas",
                                                 knead_min_dim=MIN_DIM))
    dense = get_config("smollm-360m", smoke=True)
    dparams = LanguageModel(dense).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="MoE"):
        ServingEngine(dense, dparams, ServingConfig(expert_shards=2,
                                                    impl="pallas"))


# ------------------------------- EP vs all-local subprocess oracle

_ENGINE_RUN = textwrap.dedent("""
    import json, sys
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_config
    from repro.core import routing_stats
    from repro.inference.engine import ServingConfig, ServingEngine

    from repro.models.lm import LanguageModel

    expert_shards = int(sys.argv[2])
    model_shards = int(sys.argv[3])
    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    params = LanguageModel(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    eng = ServingEngine(cfg, params, ServingConfig(
        max_len=48, impl="pallas", knead_min_dim=8,
        expert_shards=expert_shards, shards=model_shards))
    with eng._mesh_ctx():
        logits, _ = eng._prefill(eng.params, {"tokens": toks})
    gen = eng.generate({"tokens": toks}, 32)
    np.save(sys.argv[1] + "_logits.npy",
            np.asarray(logits.astype(np.float32)))
    np.save(sys.argv[1] + "_gen.npy", np.asarray(gen))
    stats = eng.latency_stats()
    meta = {"devices": jax.device_count(),
            "routed": stats.get("routed_tokens", 0),
            "work": {k: v.tolist()
                     for k, v in eng.expert_work_table().items()}}
    print(json.dumps(meta))
""")


def _run(code, out_prefix, expert_shards, model_shards, extra_env):
    env = {"PYTHONPATH": "src", "PATH": os.environ.get("PATH",
                                                       "/usr/bin:/bin")}
    env.update(extra_env)
    res = subprocess.run([sys.executable, "-c", code, out_prefix,
                          str(expert_shards), str(model_shards)],
                         capture_output=True, text=True, env=env,
                         cwd=".", timeout=1200)
    assert res.returncode == 0, res.stderr[-2000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


@pytest.fixture(scope="module")
def oracle_run(tmp_path_factory):
    """The clean single-device all-experts-local engine run, computed once
    for every EP parametrization."""
    prefix = str(tmp_path_factory.mktemp("moe_oracle") / "oracle")
    meta = _run(_ENGINE_RUN, prefix, 0, 0, {"JAX_PLATFORMS": "cpu"})
    return prefix, meta


@pytest.mark.parametrize("expert_shards,model_shards",
                         [(2, 0), (4, 0), (2, 2)])
def test_expert_sharded_engine_bit_exact_vs_all_local_oracle(
        expert_shards, model_shards, tmp_path, oracle_run):
    """ACCEPTANCE: the expert-sharded engine (EP ∈ {2, 4}, plus the 2-D
    expert×model mesh) on forced host devices produces qwen3-moe prefill
    logits AND 32-token greedy generations bit-identical to the all-local
    single-device oracle — same slot routing, same f32 scatter-add combine
    pairing, psum over "expert" only adds exact zeros from non-owning
    shards."""
    oracle_prefix, oracle_meta = oracle_run
    n_force = int(os.environ.get("REPRO_SHARD_TEST_DEVICES", "4"))
    meta = _run(
        _ENGINE_RUN, str(tmp_path / "ep"), expert_shards, model_shards,
        {"XLA_FLAGS": f"--xla_force_host_platform_device_count={n_force}",
         "JAX_PLATFORMS": "cpu"})
    assert meta["devices"] == n_force
    assert oracle_meta["devices"] == 1
    np.testing.assert_array_equal(
        np.load(tmp_path / "ep_logits.npy"),
        np.load(oracle_prefix + "_logits.npy"))
    np.testing.assert_array_equal(
        np.load(tmp_path / "ep_gen.npy"),
        np.load(oracle_prefix + "_gen.npy"))
    # routing counters and static work tables agree with the oracle's —
    # placement must not change what routes where
    assert meta["routed"] == oracle_meta["routed"]
    assert meta["work"] == oracle_meta["work"]
