"""Per-arch smoke tests (reduced configs, one fwd/train step on CPU) +
attention/SSM equivalence properties + decode==full-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import layers, ssm
from repro.models.lm import LanguageModel

B, S = 2, 32


def _batch(cfg, key, seq=S):
    b = {"tokens": jax.random.randint(key, (B, seq), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (B, seq), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        b["image_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_trainstep(arch):
    """Instantiate the reduced config, run forward + one SGD step: shapes
    correct, loss finite, gradients finite and nonzero."""
    cfg = get_config(arch, smoke=True)
    model = LanguageModel(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)

    logits = model.logits(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0

    # one SGD step reduces nothing catastrophic (loss stays finite)
    params2 = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                           params, grads)
    loss2 = model.loss(params2, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_matches_full_forward(arch):
    """prefill(S) + decode_step(S) logits == full forward at position S."""
    cfg = get_config(arch, smoke=True)
    model = LanguageModel(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = _batch(cfg, key, seq=S + 1)
    full = model.logits(params, batch)

    pre = dict(batch, tokens=batch["tokens"][:, :S])
    pre.pop("labels")
    _, cache = model.prefill(params, pre)

    def pad(x):
        if x.ndim >= 4 and x.shape[-3] == S:
            pads = [(0, 0)] * x.ndim
            pads[-3] = (0, 16)
            return jnp.pad(x, pads)
        return x
    cache = jax.tree.map(pad, cache)
    tok = batch["tokens"][:, S:S + 1]
    pos = jnp.full((B,), S, jnp.int32)
    dec, _ = model.decode_step(params, tok, pos, cache)
    err = jnp.max(jnp.abs(dec.astype(jnp.float32)
                          - full[:, -1].astype(jnp.float32)))
    scale = jnp.max(jnp.abs(full[:, -1].astype(jnp.float32))) + 1e-6
    assert float(err / scale) < 0.05    # bf16 accumulation tolerance


# ----------------------------------------------------------- attention eqv
def test_flash_equals_full_attention_and_grads():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 128, 2, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 128, 2, 16))
    ref = layers.full_attention(q, k, v, causal=True)
    out = layers.flash_attention(q, k, v, True, 32, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    g1 = jax.grad(lambda *a: jnp.sum(
        jnp.tanh(layers.full_attention(*a, causal=True))), (0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: jnp.sum(
        jnp.tanh(layers.flash_attention(*a, True, 32, 0))), (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_masked_equals_flash_forward():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 256, 1, 3, 32))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 256, 1, 32))
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 256, 1, 32))
    a = layers.chunked_attention(q, k, v, causal=True, chunk=64, exact=False)
    b = layers.flash_attention(q, k, v, True, 64, 0)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-5)


# ------------------------------------------------------------------ SSD eqv
def test_ssd_chunked_equals_stepwise():
    key = jax.random.PRNGKey(0)
    Bz, L, H, p, n = 2, 48, 2, 8, 4
    ks = jax.random.split(key, 4)
    u = jax.random.normal(ks[0], (Bz, L, H, p))
    b = jax.random.normal(ks[1], (Bz, L, H, n))
    c = jax.random.normal(ks[2], (Bz, L, H, n))
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (Bz, L, H)))
    y_c, h_c = ssm.ssd_chunked(u, b, c, log_a, chunk=16)
    h = jnp.zeros((Bz, H, p, n))
    ys = []
    for t in range(L):
        y, h = ssm.ssd_step(u[:, t], b[:, t], c[:, t], log_a[:, t], h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_c), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_c), atol=1e-4)


def test_causal_conv_streaming_equals_batch():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 20, 6))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 6)) * 0.3
    y_full, _ = ssm.causal_conv(x, w)
    state = None
    outs = []
    for t in range(20):
        y, state = ssm.causal_conv(x[:, t:t + 1], w, state)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y_full), atol=1e-5)
