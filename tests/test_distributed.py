"""Distribution tests: sharding rules, multi-device correctness (subprocess
with a forced host-device count so the main test process keeps 1 device),
MoE EP equivalence, pipeline parallelism, HLO analysis."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime import hlo_analysis, sharding

# --------------------------------------------------------------- HLO parser
_SAMPLE_HLO = """
HloModule jit_f

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %dot = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%dot), to_apply=%cond
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %ar)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_hlo_parser_trip_counts_and_dots():
    stats = hlo_analysis.analyze_hlo(_SAMPLE_HLO)
    # 10 iterations x (2*8*8*8) flops
    assert stats.dot_flops == pytest.approx(10 * 2 * 8 * 8 * 8)
    assert stats.collective_bytes["all-reduce"] == pytest.approx(
        10 * 8 * 8 * 4)


def test_hlo_parser_known_trip_count():
    hlo = _SAMPLE_HLO.replace(
        "while(%t0), condition=%cond, body=%body",
        'while(%t0), condition=%cond, body=%body, '
        'backend_config={"known_trip_count":{"n":"7"}}')
    stats = hlo_analysis.analyze_hlo(hlo)
    assert stats.dot_flops == pytest.approx(7 * 2 * 8 * 8 * 8)


# ------------------------------------------------------------ param specs
def _mk_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_spec_rules():
    mesh = jax.make_mesh((1,), ("data",))   # divisibility vacuous at size 1
    # on a 1-sized mesh everything divides; check the axis choices
    assert sharding.param_spec("layers/attn/wq", (32, 4096, 4096), mesh) \
        == P(None, ("data",), None)
    spec = sharding.param_spec("layers/mlp/wo", (32, 14336, 4096), mesh)
    assert spec == P(None, None, ("data",))   # reversed: model first (absent)


def test_param_spec_moe_and_embed():
    mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 2)))
    assert sharding.param_spec("layers/moe/wi", (4, 128, 512, 1024), mesh) \
        == P(None, "model", ("data",), None)
    assert sharding.param_spec("embed", (1024, 512), mesh) \
        == P("model", ("data",))
    assert sharding.param_spec("unembed", (512, 1024), mesh) \
        == P(("data",), "model")
    # indivisible dims fall back to None
    assert sharding.param_spec("layers/attn/wq", (2, 513, 1023), mesh) \
        == P(None, None, None)
    # sLSTM recurrent table is replicated by design
    assert sharding.param_spec("groups/slstm/r", (6, 4, 512, 2048), mesh) \
        == P()


def test_cache_sharding_seq_over_model():
    mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 2)))
    cache = {"k": jax.ShapeDtypeStruct((8, 4, 8192, 2, 16), jnp.bfloat16),
             "k_scale": jax.ShapeDtypeStruct((8, 4, 8192, 2), jnp.float32),
             "ssm": jax.ShapeDtypeStruct((8, 4, 5, 7), jnp.float32)}
    sh = sharding.cache_spec_sharding(cache, mesh, batch=4)
    assert sh["k"].spec == P(None, ("data",), "model", None, None)
    assert sh["k_scale"].spec == P(None, ("data",), "model", None)
    # small seq axes (SSM states) stay batch-only
    assert sh["ssm"].spec == P(None, ("data",), None, None)


# ----------------------------------------------- multi-device via subprocess
_SUBPROCESS_MOE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, json
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.models import blocks
    from repro.models.lm import LanguageModel
    from repro.runtime import pspec

    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)   # 8 experts, top-2
    model = LanguageModel(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}
    loss_1dev = float(model.loss(params, batch))         # no mesh: local MoE

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    from repro.runtime import sharding as shd
    pshard = shd.tree_shardings(jax.eval_shape(lambda: params), mesh)
    params_s = jax.tree.map(lambda x, s: jax.device_put(x, s), params, pshard)
    with pspec.axis_rules(mesh):
        loss_mesh = float(jax.jit(model.loss)(params_s, batch))
    print(json.dumps({"loss_1dev": loss_1dev, "loss_mesh": loss_mesh}))
""")


@pytest.mark.slow
def test_moe_ep_matches_single_device():
    """MoE expert-parallel dispatch under shard_map on a real 2x4 mesh must
    equal the single-device dispatch bit-for-bit (same capacity policy)."""
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_MOE], capture_output=True,
        text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=".", timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(r["loss_1dev"] - r["loss_mesh"]) < 2e-2, r


_SUBPROCESS_PP = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, json
    import jax.numpy as jnp
    from repro.runtime.pipeline import pipeline_apply

    mesh = jax.make_mesh((4,), ("pod",))
    L, M, mb, D = 8, 8, 2, 16
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (L, D, D)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))

    def layer(p, h):
        return jnp.tanh(h @ p)

    # reference: plain scan
    def ref_one(h):
        def body(c, p):
            return layer(p, c), None
        return jax.lax.scan(body, h, w)[0]
    ref = jax.vmap(ref_one)(x)

    out = pipeline_apply(layer, w, x, mesh, stage_axis="pod")
    err = float(jnp.max(jnp.abs(out - ref)))
    print(json.dumps({"err": err}))
""")


@pytest.mark.slow
def test_pipeline_parallel_matches_scan():
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PP], capture_output=True,
        text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=".", timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["err"] < 1e-5, r


def test_compressed_psum_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, json
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.optim.grad_comp import compressed_psum

        mesh = jax.make_mesh((4,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))

        def f(xl):
            return compressed_psum(xl[0], "data")
        out = shard_map(f, mesh=mesh, in_specs=P("data", None),
                        out_specs=P(), check_rep=False)(x)
        ref = jnp.sum(x, 0)
        rel = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
        print(json.dumps({"rel": rel}))
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["rel"] < 0.05, r   # int8-compressed reduction, bounded error
