"""Training-substrate tests: optimizer, data, checkpointing, fault
tolerance, gradient compression, trainer end-to-end with restart."""

import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.checkpoint import checkpointer as ckpt
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.optim import adamw, grad_comp
from repro.runtime import fault_tolerance as ft
from repro.train.step import TrainStepConfig, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")


# ------------------------------------------------------------------- adamw
def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=100)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(params, cfg)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adamw_grad_clip():
    cfg = adamw.AdamWConfig(lr=1e-3, grad_clip=1.0)
    params = {"w": jnp.ones((4,))}
    state = adamw.init(params, cfg)
    _, _, m = adamw.update({"w": jnp.full((4,), 1e6)}, state, params, cfg)
    assert float(m["grad_norm"]) > 1e6   # reported pre-clip


def test_adamw_bf16_state_dtype():
    cfg = adamw.AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones((8,))}
    state = adamw.init(params, cfg)
    assert state.m["w"].dtype == jnp.bfloat16


# -------------------------------------------------------------------- data
@given(step=st.integers(0, 1000))
def test_data_deterministic(step):
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4)
    ds = SyntheticTokens(cfg)
    a = ds.global_batch(step)
    b = ds.global_batch(step)
    assert bool(jnp.array_equal(a["tokens"], b["tokens"]))
    assert bool(jnp.all(a["tokens"] >= 0)) and bool(
        jnp.all(a["tokens"] < 128))
    # labels are next-token shifted
    full_a = ds.global_batch(step)
    assert bool(jnp.array_equal(a["labels"][:, :-1], a["tokens"][:, 1:]))


def test_data_host_slices_partition():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=8)
    ds = SyntheticTokens(cfg)
    full = ds.global_batch(3)["tokens"]
    parts = [ds.host_batch(3, i, 4)["tokens"] for i in range(4)]
    assert bool(jnp.array_equal(jnp.concatenate(parts), full))


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.zeros((), jnp.int32)}
    ckpt.save(tmp_path, 7, tree)
    assert ckpt.latest_step(tmp_path) == 7
    out = ckpt.restore(tmp_path, 7, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert bool(jnp.array_equal(x, jnp.asarray(y)))


def test_checkpoint_atomicity(tmp_path):
    tree = {"a": jnp.ones((3,))}
    ckpt.save(tmp_path, 1, tree)
    # a stale tmp dir from a crashed save must not break the next save
    (tmp_path / "step_00000002.tmp").mkdir()
    ckpt.save(tmp_path, 2, tree)
    assert ckpt.latest_step(tmp_path) == 2


def test_async_checkpointer(tmp_path):
    ac = ckpt.AsyncCheckpointer(tmp_path)
    ac.save(3, {"w": jnp.ones((5,))})
    ac.wait()
    assert ckpt.latest_step(tmp_path) == 3


# --------------------------------------------------------- grad compression
@given(seed=st.integers(0, 30))
def test_grad_compression_error_bound(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    deq, err = grad_comp.compress_decompress({"w": g}, None)
    # int8 quantization error is bounded by scale/2 per element
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(deq["w"] - g))) <= scale * 0.51 + 1e-7
    # error feedback: carry equals the exact residual
    assert float(jnp.max(jnp.abs(err["w"] - (g - deq["w"])))) < 1e-6


def test_grad_compression_error_feedback_accumulates():
    """A constant tiny gradient must eventually pass through via EF."""
    g = {"w": jnp.full((8,), 1e-4)}
    big = {"w": jnp.ones((8,))}     # sets the scale so 1e-4 rounds to zero
    err = None
    total = jnp.zeros((8,))
    for i in range(50):
        grads = {"w": big["w"] * (i == 0) + g["w"]}
        deq, err = grad_comp.compress_decompress(grads, err)
        total = total + deq["w"]
    # after enough steps the accumulated deq approximates the true sum
    true = 1.0 + 50 * 1e-4
    assert float(jnp.abs(total - true).max()) < 0.02


# ------------------------------------------------------------------ faults
def test_failure_injector_and_restart_loop():
    inj = ft.FailureInjector(fail_at_steps=[3])
    done = []

    def step(i):
        inj.maybe_fail(i)
        done.append(i)

    restarts = ft.run_resilient_loop(
        start_step=0, num_steps=6, step_fn=step,
        restore_fn=lambda: 2)
    assert restarts == 1
    assert done == [0, 1, 2, 3, 4, 5] or done == [0, 1, 2, 2, 3, 4, 5]


def test_step_timer_flags_stragglers():
    t = ft.StepTimer(k=3.0, warmup=2)
    import time
    for i in range(5):
        t.start()
        time.sleep(0.12 if i == 4 else 0.005)
        t.stop(i)
    assert 4 in t.straggler_steps


# ------------------------------------------------------ trainer end-to-end
def test_trainer_restart_is_consistent(tmp_path):
    """Same seeds, one run with an injected failure, one without: the
    recovered run must land on the same step count and a close loss."""
    cfg = get_config("smollm-360m", smoke=True)

    def run(inject, d):
        tc = TrainerConfig(num_steps=12, ckpt_every=5, ckpt_dir=str(d),
                           log_every=100)
        inj = ft.FailureInjector(fail_at_steps=[8]) if inject else None
        tr = Trainer(cfg, tc, global_batch=4, seq_len=32, injector=inj)
        tr.run()
        return tr

    t1 = run(False, tmp_path / "a")
    t2 = run(True, tmp_path / "b")
    assert t2.restarts == 1
    l1 = jax.tree.leaves(t1.params)
    l2 = jax.tree.leaves(t2.params)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(l1, l2))
    assert err < 2e-2    # resumed-from-step-5 trajectory, close not exact


def test_train_step_microbatch_equivalence():
    """Gradient accumulation over microbatches == full-batch gradients."""
    from repro.models.lm import LanguageModel
    cfg = get_config("llama3-8b", smoke=True)
    model = LanguageModel(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}
    outs = {}
    for mb in (0, 2):
        ts = TrainStepConfig(microbatch=mb)
        step = make_train_step(model, ts)
        opt = adamw.init(params, ts.optimizer)
        p2, _, _, m = jax.jit(step)(params, opt, batch, None)
        outs[mb] = (p2, float(m["loss"]))
    assert abs(outs[0][1] - outs[2][1]) < 1e-2
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[2][0])))
    assert err < 5e-2   # adam normalizes; bf16 accumulation tolerance
