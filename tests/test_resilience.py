"""Chaos suite for the serving fault policy (docs/DESIGN.md §10).

The load-bearing guarantee is the acceptance bar of the resilience PR:
under injected faults — a kernel exception at a chosen decode step, a
persistently NaN-poisoned request, a corrupted kneaded plane repaired by
re-knead — every *surviving* request's drain() output is **bit-identical**
to a fault-free run, on the planes and pallas impls alike, while the
injected request fails within ``max_retries`` and ``latency_stats()``
reports the retry/straggler/degradation counters.  Around that: the
NaN-logit quarantine (transient vs persistent), retry exhaustion and the
``RequestFailed`` error surface, cancel and deadline expiry during a
retry-backoff window, the graceful-degradation ladder, slot-loss
recovery, kneaded-weight checksum verification + repair, checkpoint
per-leaf CRCs, and the training restart-loop backoff fixes.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ckpt
from repro.configs.registry import get_config
from repro.core.kneading import (KneadedIntegrityError, knead_padded,
                                 reknead_like)
from repro.core.schedule import shard_schedule
from repro.inference.engine import ServingConfig, ServingEngine
from repro.inference.frontend import DeadlineExceeded, RequestFailed
from repro.inference.kv_pool import KVBlockPool
from repro.inference.resilience import (EngineFaultInjector,
                                        ServingFaultPolicy, corrupt_kneaded)
from repro.models.lm import LanguageModel
from repro.runtime import fault_tolerance as ft

MIN_DIM = 8      # knead smoke-size projections too


@pytest.fixture(scope="module")
def smol():
    cfg = get_config("smollm-360m", smoke=True)
    params = LanguageModel(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _engine(smol, impl="float", **kw):
    cfg, params = smol
    defaults = dict(max_len=48, impl=impl, knead_min_dim=MIN_DIM,
                    buckets=(1, 2, 4), scheduler="continuous",
                    max_inflight=3, kv_block=16)
    defaults.update(kw)
    return ServingEngine(cfg, params, ServingConfig(**defaults))


def _submit_set(eng, cfg, spec=((6, 5), (6, 3), (9, 4))):
    handles = []
    for i, (plen, n) in enumerate(spec):
        toks = jax.random.randint(jax.random.PRNGKey(50 + i), (plen,), 0,
                                  cfg.vocab_size)
        handles.append(eng.submit(toks, n))
    return handles


def _policy(**kw):
    defaults = dict(max_retries=2, retry_backoff_s=0.005)
    defaults.update(kw)
    return ServingFaultPolicy(**defaults)


# ------------------------------------------------- step-fault recovery


def test_decode_fault_recovery_bit_identical(smol):
    """An injected kernel exception mid-decode requeues every in-flight
    request; the replayed generations match a fault-free run bitwise."""
    cfg, _ = smol
    ref = _engine(smol)
    _submit_set(ref, cfg)
    want = ref.drain()
    eng = _engine(smol, fault_policy=_policy(
        injector=EngineFaultInjector(fail_decode_steps=(2,))))
    handles = _submit_set(eng, cfg)
    got = eng.drain()
    assert sorted(got) == sorted(want)
    for rid in want:
        assert np.array_equal(np.asarray(got[rid]), np.asarray(want[rid]))
    stats = eng.latency_stats()
    assert stats["recoveries"] == 1 and stats["retries"] >= 1
    assert all(h.state == "done" for h in handles)


def test_prefill_fault_recovery(smol):
    cfg, _ = smol
    ref = _engine(smol)
    _submit_set(ref, cfg)
    want = ref.drain()
    eng = _engine(smol, fault_policy=_policy(
        injector=EngineFaultInjector(fail_prefill_steps=(0,))))
    _submit_set(eng, cfg)
    got = eng.drain()
    for rid in want:
        assert np.array_equal(np.asarray(got[rid]), np.asarray(want[rid]))
    assert eng.latency_stats()["recoveries"] == 1


def test_slot_loss_recovery(smol):
    """Simulated loss of one slot's device state replays only that
    request; everything else decodes on undisturbed."""
    cfg, _ = smol
    ref = _engine(smol)
    _submit_set(ref, cfg)
    want = ref.drain()
    eng = _engine(smol, fault_policy=_policy(
        injector=EngineFaultInjector(lose_slot_steps=((1, 0),))))
    _submit_set(eng, cfg)
    got = eng.drain()
    for rid in want:
        assert np.array_equal(np.asarray(got[rid]), np.asarray(want[rid]))
    stats = eng.latency_stats()
    assert stats["slot_losses"] == 1
    assert stats.get("recoveries", 0) == 0     # zero counters are omitted


# ---------------------------------------------------- NaN quarantine


def test_nan_quarantine_only_offending_request(smol):
    """A persistently NaN-poisoned request FAILs within max_retries;
    its batchmates' outputs stay bit-identical to a fault-free run."""
    cfg, _ = smol
    ref = _engine(smol)
    _submit_set(ref, cfg)
    want = ref.drain()
    eng = _engine(smol, fault_policy=_policy(
        injector=EngineFaultInjector(nan_request_ids=(1,))))
    handles = _submit_set(eng, cfg)
    got = eng.drain()
    assert sorted(got) == [0, 2]
    for rid in got:
        assert np.array_equal(np.asarray(got[rid]), np.asarray(want[rid]))
    assert handles[1].state == "failed"
    assert handles[1].retries == 3          # max_retries=2 + the final try
    assert "non-finite" in handles[1].error
    with pytest.raises(RequestFailed, match="request 1 failed"):
        handles[1].result()
    stats = eng.latency_stats()
    assert stats["nan_quarantined"] == 3 and stats["failed_requests"] == 1


def test_nan_transient_recovers(smol):
    """nan_once models a transient glitch: the retry replays cleanly and
    the request completes bit-identically."""
    cfg, _ = smol
    ref = _engine(smol)
    _submit_set(ref, cfg)
    want = ref.drain()
    eng = _engine(smol, fault_policy=_policy(
        injector=EngineFaultInjector(nan_request_ids=(0,), nan_once=True)))
    handles = _submit_set(eng, cfg)
    got = eng.drain()
    assert sorted(got) == [0, 1, 2]
    for rid in want:
        assert np.array_equal(np.asarray(got[rid]), np.asarray(want[rid]))
    assert handles[0].retries == 1


# ------------------------------------------- retries, backoff, deadlines


def test_retry_exhaustion_fails_terminally(smol):
    cfg, _ = smol
    eng = _engine(smol, fault_policy=_policy(
        max_retries=1,
        injector=EngineFaultInjector(nan_request_ids=(0,))))
    toks = jax.random.randint(jax.random.PRNGKey(1), (6,), 0,
                              cfg.vocab_size)
    h = eng.submit(toks, 4)
    assert eng.drain() == {}
    assert h.state == "failed" and h.retries == 2
    # FAILED is terminal: not cancellable, not re-queued
    assert not h.cancel()
    assert not eng.scheduler_step()


def test_cancel_during_retry_backoff(smol):
    """A request sitting out its backoff window is still QUEUED — cancel
    withdraws it before the retry fires."""
    cfg, _ = smol
    eng = _engine(smol, fault_policy=_policy(
        retry_backoff_s=30.0,     # parks the retry far in the future
        injector=EngineFaultInjector(fail_prefill_steps=(0,))))
    toks = jax.random.randint(jax.random.PRNGKey(1), (6,), 0,
                              cfg.vocab_size)
    h = eng.submit(toks, 4)
    eng.scheduler_step()          # fault -> requeued with retry_at set
    assert h.state == "queued" and h.retries == 1
    assert h.cancel()
    assert h.state == "cancelled"
    assert not eng.scheduler_step()


def test_deadline_expires_during_backoff(smol):
    """Deadlines keep applying to re-queued requests: a retry parked
    past its deadline expires instead of replaying."""
    cfg, _ = smol
    eng = _engine(smol, fault_policy=_policy(
        retry_backoff_s=0.05,
        injector=EngineFaultInjector(fail_prefill_steps=(0,))))
    toks = jax.random.randint(jax.random.PRNGKey(1), (6,), 0,
                              cfg.vocab_size)
    h = eng.submit(toks, 4, deadline=0.02)
    eng.scheduler_step()          # fault -> backoff window > deadline
    time.sleep(0.03)
    eng.scheduler_step()
    assert h.state == "expired"
    with pytest.raises(DeadlineExceeded):
        h.result()


def test_backoff_window_delays_readmission(smol):
    cfg, _ = smol
    pol = _policy(retry_backoff_s=0.05, backoff_mult=3.0, backoff_cap_s=0.1)
    assert pol.backoff_for(1) == pytest.approx(0.05)
    assert pol.backoff_for(2) == pytest.approx(0.1)    # capped, not 0.15
    eng = _engine(smol, fault_policy=dataclasses.replace(
        pol, injector=EngineFaultInjector(fail_prefill_steps=(0,))))
    toks = jax.random.randint(jax.random.PRNGKey(1), (6,), 0,
                              cfg.vocab_size)
    h = eng.submit(toks, 2)
    t0 = time.perf_counter()
    eng.drain()
    assert time.perf_counter() - t0 >= 0.05    # sat out the window
    assert h.state == "done" and h.retries == 1


# ------------------------------------------------------------ watchdog


def test_watchdog_flags_slow_steps(smol):
    """step_timeout_s far below any real launch time: every decode step
    counts a watchdog timeout (surfaced via latency_stats), and with
    timeout_is_fault=False the work still completes."""
    cfg, _ = smol
    eng = _engine(smol, fault_policy=_policy(step_timeout_s=1e-9))
    _submit_set(eng, cfg)
    got = eng.drain()
    assert sorted(got) == [0, 1, 2]
    assert eng.latency_stats()["watchdog_timeouts"] >= 1


def test_watchdog_timeout_as_fault_exhausts_retries(smol):
    """timeout_is_fault escalates every (always-slow) step to the
    recovery path until retries exhaust — requests FAIL, loop survives."""
    cfg, _ = smol
    eng = _engine(smol, fault_policy=_policy(
        max_retries=1, step_timeout_s=1e-9, timeout_is_fault=True))
    toks = jax.random.randint(jax.random.PRNGKey(1), (6,), 0,
                              cfg.vocab_size)
    h = eng.submit(toks, 4)
    assert eng.drain() == {}
    assert h.state == "failed"
    stats = eng.latency_stats()
    assert stats["watchdog_timeouts"] >= 2 and stats["recoveries"] >= 2


# ------------------------------------------------- degradation ladder


def test_demotion_pallas_to_planes_bit_exact(smol):
    """Two consecutive step faults demote pallas -> planes; since planes
    is the kernel's bitwise oracle, the completed generations still match
    the fault-free pallas reference exactly."""
    cfg, _ = smol
    ref = _engine(smol, impl="pallas")
    _submit_set(ref, cfg)
    want = ref.drain()
    eng = _engine(smol, impl="pallas", fault_policy=_policy(
        max_retries=3, demote_after=2,
        injector=EngineFaultInjector(fail_decode_steps=(1, 2))))
    _submit_set(eng, cfg)
    got = eng.drain()
    assert eng.scfg.impl == "planes" and eng.cfg.impl == "planes"
    assert sorted(got) == sorted(want)
    for rid in want:
        assert np.array_equal(np.asarray(got[rid]), np.asarray(want[rid]))
    stats = eng.latency_stats()
    assert stats["degradations"] == 1 and stats["recoveries"] == 2
    events = [e for e in eng.fault_events() if e["kind"] == "degradations"]
    assert events[0]["impl_from"] == "pallas"
    assert events[0]["impl_to"] == "planes"


def test_demotion_ladder_ends(smol):
    """Demotion stops at the ladder's last rung instead of cycling."""
    eng = _engine(smol, impl="planes",
                  fault_policy=_policy(fallback_impls=("planes", "float")))
    assert eng._demote_impl("test") and eng.scfg.impl == "float"
    assert not eng._demote_impl("test")    # no rung below float
    assert eng.scfg.impl == "float"


# ------------------------------------- activation skip under faults


def test_decode_fault_recovery_with_activation_skip(smol):
    """Chaos x two-sided skip (docs/DESIGN.md §12): a kernel exception
    mid-decode with ``activation_skip=True`` recovers via the full-prompt
    replay and the survivors stay bit-identical to BOTH a fault-free
    skip-on run and a fault-free skip-off run — fault recovery and the
    activation-occupancy mask compose without moving a bit."""
    cfg, _ = smol
    want = {}
    for skip in (False, True):
        ref = _engine(smol, impl="pallas", activation_skip=skip)
        _submit_set(ref, cfg)
        want[skip] = ref.drain()
    for rid in want[False]:
        assert np.array_equal(np.asarray(want[True][rid]),
                              np.asarray(want[False][rid]))
    eng = _engine(smol, impl="pallas", activation_skip=True,
                  fault_policy=_policy(
                      injector=EngineFaultInjector(fail_decode_steps=(2,))))
    handles = _submit_set(eng, cfg)
    got = eng.drain()
    assert sorted(got) == sorted(want[True])
    for rid in want[True]:
        assert np.array_equal(np.asarray(got[rid]),
                              np.asarray(want[True][rid]))
    stats = eng.latency_stats()
    assert stats["recoveries"] == 1 and stats["retries"] >= 1
    assert all(h.state == "done" for h in handles)


def test_demotion_preserves_activation_skip(smol):
    """The degradation ladder replaces only ``impl``: after pallas ->
    planes demotion the engine still carries ``activation_skip=True``
    (planes replays the intersected order in its oracle), and the
    completed generations match the fault-free skip-off pallas reference
    bit-for-bit."""
    cfg, _ = smol
    ref = _engine(smol, impl="pallas")
    _submit_set(ref, cfg)
    want = ref.drain()
    eng = _engine(smol, impl="pallas", activation_skip=True,
                  fault_policy=_policy(
                      max_retries=3, demote_after=2,
                      injector=EngineFaultInjector(fail_decode_steps=(1, 2))))
    _submit_set(eng, cfg)
    got = eng.drain()
    assert eng.scfg.impl == "planes" and eng.cfg.impl == "planes"
    assert eng.scfg.activation_skip and eng.cfg.activation_skip
    assert sorted(got) == sorted(want)
    for rid in want:
        assert np.array_equal(np.asarray(got[rid]), np.asarray(want[rid]))
    assert eng.latency_stats()["degradations"] == 1


# ------------------------------------------- kneaded-weight integrity


def test_kneaded_checksums_detect_corruption():
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 32))
    kw = knead_padded(w, bits=4, ks=16, n_block=16)
    assert kw.verify() == ()
    for field in ("occupancy", "planes", "schedule.counts",
                  "schedule.plane_ids"):
        bad = corrupt_kneaded(kw, field, flat_index=1)
        assert bad.verify() == (field,)
        with pytest.raises(KneadedIntegrityError, match=field):
            bad.verify(strict=True)


def test_reknead_repairs_bit_identically():
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 32))
    kw = knead_padded(w, bits=4, ks=16, n_block=16)
    bad = corrupt_kneaded(kw, "planes", flat_index=2)
    fixed = reknead_like(bad, w)
    assert fixed.verify() == ()
    for field in ("planes", "signs", "scale", "occupancy"):
        assert np.array_equal(np.asarray(getattr(fixed, field)),
                              np.asarray(getattr(kw, field))), field
    assert fixed.checksums == kw.checksums


def test_sharded_checksums_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(4), (64, 64))
    skw = shard_schedule(knead_padded(w, bits=4, ks=16, n_block=16), 2)
    assert skw.verify() == ()
    bad = dataclasses.replace(
        skw, counts=jnp.asarray(np.asarray(skw.counts) + 1))
    assert "counts" in bad.verify()


def test_engine_verify_weights_repairs(smol):
    """Corrupt one kneaded plane inside a live engine; verify_weights
    re-kneads it from the retained float checkpoint and subsequent
    serving is bit-identical to an untouched engine."""
    from repro.core.kneading import KneadedWeight

    cfg, _ = smol
    ref = _engine(smol, impl="planes")
    _submit_set(ref, cfg)
    want = ref.drain()
    eng = _engine(smol, impl="planes", fault_policy=_policy())
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        eng.params, is_leaf=lambda x: isinstance(x, KneadedWeight))
    leaves, hit = [], False
    for _, leaf in flat:
        if isinstance(leaf, KneadedWeight) and not hit:
            leaf, hit = corrupt_kneaded(leaf, "planes", flat_index=3), True
        leaves.append(leaf)
    assert hit
    eng.params = jax.tree_util.tree_unflatten(treedef, leaves)
    report = eng.verify_weights()
    assert len(report) == 1 and report[0]["repaired"]
    assert report[0]["fields"] == ("planes",)
    assert eng.verify_weights() == []          # clean after repair
    _submit_set(eng, cfg)
    got = eng.drain()
    for rid in want:
        assert np.array_equal(np.asarray(got[rid]), np.asarray(want[rid]))
    assert eng.latency_stats()["integrity_repairs"] == 1


# ------------------------------------------------ checkpoint integrity


def _save_tree(tmp_path):
    tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
            "b": np.ones(8, dtype=np.float32)}
    d = ckpt.save(tmp_path, 3, tree)
    return tree, d


def test_checkpoint_crc_in_manifest(tmp_path):
    import json
    tree, d = _save_tree(tmp_path)
    manifest = json.loads((d / "manifest.json").read_text())
    assert all("crc32" in leaf for leaf in manifest["leaves"])
    out = ckpt.restore(tmp_path, 3, tree)
    assert np.array_equal(np.asarray(out["w"]), tree["w"])


def test_checkpoint_bitflip_detected(tmp_path):
    tree, d = _save_tree(tmp_path)
    leaf = d / "leaf_0.npy"
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0x40                    # flip a payload bit
    leaf.write_bytes(bytes(raw))
    with pytest.raises(ckpt.CheckpointCorrupt, match="leaf 0"):
        ckpt.restore(tmp_path, 3, tree)


def test_checkpoint_truncation_detected(tmp_path):
    tree, d = _save_tree(tmp_path)
    leaf = d / "leaf_1.npy"
    leaf.write_bytes(leaf.read_bytes()[:40])    # torn write
    with pytest.raises(ckpt.CheckpointCorrupt, match="leaf 1"):
        ckpt.restore(tmp_path, 3, tree)


def test_checkpoint_leaf_count_mismatch(tmp_path):
    tree, _ = _save_tree(tmp_path)
    bigger = dict(tree, extra=np.zeros(3, np.float32))
    with pytest.raises(ckpt.CheckpointCorrupt, match="leaves"):
        ckpt.restore(tmp_path, 3, bigger)


# ------------------------------------------------ restart-loop backoff


def test_restart_backoff_resets_after_clean_step():
    sleeps = []
    pol = ft.RestartPolicy(max_restarts=10, backoff_s=1.0, backoff_mult=2.0)
    fails = {2: 2, 5: 1}                    # step -> remaining failures

    def step_fn(step):
        if fails.get(step, 0):
            fails[step] -= 1
            raise RuntimeError("boom")

    import repro.runtime.fault_tolerance as mod
    orig = mod.time.sleep
    mod.time.sleep = sleeps.append
    try:
        restarts = ft.run_resilient_loop(
            start_step=0, num_steps=8, step_fn=step_fn,
            restore_fn=lambda: 2, policy=pol)
    finally:
        mod.time.sleep = orig
    assert restarts == 3
    # consecutive faults at step 2 escalate (1, 2); the clean steps in
    # between reset the fault at step 5 back to the base backoff
    assert sleeps == [1.0, 2.0, 1.0]


def test_restart_backoff_cap_and_jitter():
    pol = ft.RestartPolicy(backoff_s=1.0, backoff_cap_s=4.0,
                           jitter_frac=0.5)
    assert pol.next_backoff(3.0) == pytest.approx(4.0)   # capped
    import random
    rng = random.Random(0)
    s = pol.sleep_s(100.0, rng=rng)
    assert 4.0 <= s <= 6.0            # cap first, then <= 50% jitter


def test_restart_loop_default_policy_not_shared():
    """policy=None builds a fresh default per call (the old shared
    mutable-default instance leaked state across callers)."""
    calls = []

    def flaky(step):
        calls.append(step)

    for _ in range(2):
        assert ft.run_resilient_loop(
            start_step=0, num_steps=2, step_fn=flaky,
            restore_fn=lambda: 0) == 0


# ------------------------------------------------------------ KV pool


def test_kv_pool_release_all():
    pool = KVBlockPool(num_slots=3, max_len=64, block=16)
    pool.alloc(0, 40)
    pool.alloc(1, 10)
    assert pool.used_blocks == 4
    assert pool.release_all() == 4
    assert pool.used_blocks == 0 and pool.extent() == 0
    pool.alloc(0, 16)                  # table usable again
    assert pool.used_blocks == 1


# ---------------------------------------------------- CNN NaN guard


def test_cnn_nan_guard_fails_only_bad_request():
    from repro.inference.cnn_engine import CNNServingConfig, CNNServingEngine
    from repro.models import cnn

    cfg = dataclasses.replace(cnn.CNN_ZOO["nin"], image_size=16)
    params = cnn.init(jax.random.PRNGKey(0), cfg)
    eng = CNNServingEngine(cfg, params, CNNServingConfig(
        impl="float", buckets=(1, 2, 4), jit=False,
        fault_policy=ServingFaultPolicy()))
    good = jax.random.normal(jax.random.PRNGKey(1), (16, 16, 3))
    bad = jnp.full((16, 16, 3), jnp.nan)
    h_good, h_bad = eng.submit(good), eng.submit(bad)
    results = eng.drain()
    assert h_good in results and h_bad not in results
    assert h_bad.state == "failed"
    with pytest.raises(RequestFailed):
        h_bad.result()
    assert eng.latency_stats()["nan_quarantined"] == 1


# ------------------------------------------------- acceptance (chaos)


@pytest.mark.parametrize("impl", ["planes", "pallas"])
def test_chaos_acceptance(smol, impl):
    """The ISSUE's acceptance bar, per impl: kernel exception at a chosen
    step + a persistently-NaN request + a corrupted plane repaired by
    re-knead, all in one run — survivors bit-identical to fault-free,
    the poisoned request FAILED within max_retries, counters reported."""
    from repro.core.kneading import KneadedWeight

    cfg, _ = smol
    ref = _engine(smol, impl=impl)
    _submit_set(ref, cfg)
    want = ref.drain()

    eng = _engine(smol, impl=impl, fault_policy=_policy(
        max_retries=2, demote_after=99,     # no demotion: isolate recovery
        injector=EngineFaultInjector(fail_decode_steps=(2,),
                                     nan_request_ids=(1,))))
    # corrupt one kneaded plane, then let the integrity path repair it
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        eng.params, is_leaf=lambda x: isinstance(x, KneadedWeight))
    leaves, hit = [], False
    for _, leaf in flat:
        if isinstance(leaf, KneadedWeight) and not hit:
            leaf, hit = corrupt_kneaded(leaf, "planes", flat_index=5), True
        leaves.append(leaf)
    eng.params = jax.tree_util.tree_unflatten(treedef, leaves)
    assert len(eng.verify_weights()) == 1

    handles = _submit_set(eng, cfg)
    got = eng.drain()
    assert sorted(got) == [0, 2]               # the poisoned request fell out
    for rid in got:
        assert np.array_equal(np.asarray(got[rid]), np.asarray(want[rid]))
    assert handles[1].state == "failed"
    assert handles[1].retries <= 2 + 1
    stats = eng.latency_stats()
    assert stats["retries"] >= 1
    assert stats["recoveries"] == 1
    assert stats["nan_quarantined"] == 3
    assert stats["failed_requests"] == 1
    assert stats["integrity_repairs"] == 1
    assert eng.scfg.impl == impl               # no demotion occurred
