"""End-to-end training driver: smollm-360m (reduced) for a few hundred steps.

Exercises the full production stack on one host: deterministic data
pipeline, pjit'd train step with gradient accumulation, async checkpointing,
an injected mid-run failure with automatic restart, and a straggler report.
The loss must descend (the synthetic stream has learnable motif structure).

Run:  PYTHONPATH=src python examples/train_smollm.py [--steps 300]
"""
import argparse
import shutil

from repro.configs.registry import get_config
from repro.optim.adamw import AdamWConfig
from repro.runtime import fault_tolerance as ft
from repro.train.step import TrainStepConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_smollm")
    ap.add_argument("--inject-failure", type=int, default=150,
                    help="step at which to inject a node failure (0=off)")
    args = ap.parse_args()

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    cfg = get_config("smollm-360m", smoke=True)
    ts = TrainStepConfig(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=20,
                              total_steps=args.steps),
        microbatch=args.batch // 2,        # exercise grad accumulation
    )
    injector = (ft.FailureInjector(fail_at_steps=[args.inject_failure])
                if args.inject_failure else None)
    tr = Trainer(cfg, TrainerConfig(num_steps=args.steps, ckpt_every=50,
                                    ckpt_dir=args.ckpt_dir, log_every=25),
                 ts=ts, global_batch=args.batch, seq_len=args.seq,
                 injector=injector)
    log = tr.run()

    print(f"\n{'step':>6} {'loss':>9} {'grad_norm':>10} {'ms/step':>9}")
    for s, m in sorted(log.items()):
        print(f"{s:6d} {m['loss']:9.4f} {m['grad_norm']:10.3f} "
              f"{m['step_time_s']*1e3:9.1f}")
    losses = [m["loss"] for _, m in sorted(log.items())]
    print(f"\nrestarts: {tr.restarts}  "
          f"stragglers flagged: {tr.timer.straggler_steps[:5]}")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'DESCENDED' if losses[-1] < losses[0] - 0.2 else 'FLAT'})")


if __name__ == "__main__":
    main()
