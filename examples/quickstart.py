"""Quickstart: the paper's technique end to end in ~60 lines.

1. Take a weight matrix, measure its zero-bit slack (Table 1).
2. Knead it (Fig 3) and show the cycle-count win of SAC over MAC (Fig 8).
3. Run the SAC matmul three ways — pure-jnp plane decomposition, integer
   epilogue, and the Pallas TPU kernel (interpret mode on CPU) — and check
   they agree bit-for-bit with the dense reference.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (cost_model, knead, kneading_ratio, quantize,
                        sac_matmul, weight_bit_stats)
from repro.kernels.sac_matmul.ops import sac_matmul_pallas
from repro.kernels.sac_matmul.ref import sac_matmul_ref


def main():
    key = jax.random.PRNGKey(0)
    # a "trained-looking" heavy-tailed weight matrix (see EXPERIMENTS.md)
    w = jax.random.t(key, 3.0, (1024, 512)) * 0.02
    a = jax.random.normal(jax.random.PRNGKey(1), (8, 1024))

    # 1. bit-level slack (paper Table 1)
    s = weight_bit_stats(w, bits=16)
    print(f"zero-value weights: {100*s.zero_value_frac:.3f}%   "
          f"zero BITs in weights: {100*s.zero_bit_frac:.2f}%  "
          f"(paper: ~0.1% / ~68.9%)")

    # 2. kneading: cycles per 16-weight group vs the MAC baseline (Fig 3/11)
    qt = quantize(w, bits=16, axis=None)
    ratio = float(kneading_ratio(qt.q, 16, ks=16))
    print(f"kneaded cycle ratio at KS=16: {100*ratio:.1f}% of DaDN "
          f"(speedup {1/ratio:.2f}x)")

    # cycle model including the PRA baseline (Fig 8)
    acts = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (1024, 32)))
    cb = cost_model.model_layer(qt.q, quantize(acts, bits=16, axis=None).q,
                                bits=16, ks=16)
    print("modeled speedups vs DaDN:", {k: round(v, 2)
                                        for k, v in cb.speedup().items()})

    # 3. SAC matmul == dense matmul, three implementations
    kw = knead(w, bits=8, ks=256)
    dense = a @ (quantize(w, bits=8).q * quantize(w, bits=8).scale)
    for impl in ("planes", "int"):
        out = sac_matmul(a, kw, impl=impl)
        err = float(jnp.max(jnp.abs(out - dense)))
        print(f"sac_matmul[{impl:6s}] max err vs dense: {err:.2e}")
    out = sac_matmul_pallas(a, kw, bm=8)           # Pallas kernel (interpret)
    err = float(jnp.max(jnp.abs(out - sac_matmul_ref(a, kw))))
    print(f"sac_matmul[pallas] max err vs oracle: {err:.2e}")
    print(f"kneaded HBM bytes vs bf16: "
          f"{kw.packed_bytes()/kw.dense_bf16_bytes():.3f}x")


if __name__ == "__main__":
    main()
