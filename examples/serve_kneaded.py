"""Serving with kneaded weights: train briefly, knead to int8/int4, compare.

Demonstrates the paper's technique as a deployment feature: the same trained
checkpoint served at bf16 / int8 / int4, with the weight-bytes reduction and
the agreement of generated tokens across precisions.

Run:  PYTHONPATH=src python examples/serve_kneaded.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.inference.engine import ServingConfig, ServingEngine, serving_bytes
from repro.optim.adamw import AdamWConfig
from repro.train.step import TrainStepConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    # small arch with >=128-dim projections so kneading actually applies
    import dataclasses
    cfg = dataclasses.replace(
        get_config("llama3-8b", smoke=True),
        d_model=256, num_heads=4, num_kv_heads=2, d_ff=512, num_layers=2)
    tr = Trainer(cfg, TrainerConfig(num_steps=60, ckpt_every=1000,
                                    ckpt_dir="/tmp/repro_serve_ex",
                                    log_every=30),
                 ts=TrainStepConfig(optimizer=AdamWConfig(lr=1e-3,
                                                          total_steps=60)),
                 global_batch=8, seq_len=64)
    tr.run()
    params = tr.params

    key = jax.random.PRNGKey(3)
    prompts = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
    outs = {}
    for bits in (0, 8, 4):
        eng = ServingEngine(cfg, params,
                            ServingConfig(max_len=64, quant_bits=bits))
        t0 = time.perf_counter()
        outs[bits] = eng.generate({"tokens": prompts}, 24)
        dt = time.perf_counter() - t0
        mb = serving_bytes(eng.params) / 1e6
        print(f"quant={bits or 'bf16':>4}: weights {mb:7.2f} MB   "
              f"gen 4x24 tok in {dt:5.2f}s")
    agree8 = float(jnp.mean((outs[8] == outs[0]).astype(jnp.float32)))
    agree4 = float(jnp.mean((outs[4] == outs[0]).astype(jnp.float32)))
    print(f"token agreement vs bf16: int8 {100*agree8:.1f}%  "
          f"int4 {100*agree4:.1f}%")
    print("sample:", outs[0][0, :12].tolist())


if __name__ == "__main__":
    main()
