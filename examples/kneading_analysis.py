"""Kneading design-space exploration on the paper's own CNNs.

Reproduces the paper's analysis pipeline interactively: trains the three
CNNs briefly, then sweeps kneading stride and bit width and prints the
cycle-model speedups + the area trade-off — the Fig 11 / Table 2 story.

Run:  PYTHONPATH=src python examples/kneading_analysis.py
"""
import pathlib
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import cnn_layer_data
from repro.core import cost_model, quantize
from repro.core.kneading import kneading_ratio


def main():
    for name in ("alexnet", "vgg16", "nin"):
        weights, acts = cnn_layer_data(name)
        big_name, big = max(weights.items(), key=lambda kv: kv[1].size)
        print(f"\n=== {name} (largest layer: {big_name} {tuple(big.shape)})")
        print(f"{'KS':>4} {'fp16 T_ks/T0':>13} {'int8 T_ks/T0':>13} "
              f"{'splitter p bits':>16}")
        for ks in (8, 10, 16, 24, 32, 64):
            q16 = quantize(big, bits=16, axis=None).q
            q8 = quantize(big, bits=8, axis=None).q
            k16 = (q16.shape[0] // ks) * ks
            r16 = float(kneading_ratio(q16[:k16], 16, ks))
            r8 = float(kneading_ratio(q8[:k16], 8, ks))
            print(f"{ks:4d} {100*r16:12.1f}% {100*r8:12.1f}% "
                  f"{int(np.ceil(np.log2(ks))):16d}")
        # end-to-end modeled speedup at the paper's operating point
        tot_d = tot_t = 0.0
        for lname, w in weights.items():
            qw = quantize(w, bits=16, axis=None)
            qa = quantize(jnp.abs(acts[lname][:2048]), bits=16, axis=None)
            c = cost_model.model_layer(qw.q, qa.q, bits=16, ks=16)
            tot_d += c.dadn
            tot_t += c.tetris
        print(f"  KS=16 end-to-end Tetris speedup: {tot_d/tot_t:.2f}x "
              f"(paper Fig 8: ~1.3x)")


if __name__ == "__main__":
    main()
