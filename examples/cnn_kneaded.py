"""The paper's own domain end to end: a kneaded VGG-16 classifier.

Trains VGG-16 briefly, converts EVERY conv/fc layer to the kneaded
bit-plane format (the Tetris deployment artifact), runs inference through
the SAC path — one layer through the actual Pallas kernel — and reports:

  * classification agreement between float and kneaded inference,
  * the per-layer kneaded HBM footprint vs bf16,
  * the modeled per-layer Tetris speedup (paper Fig 9).

Run:  PYTHONPATH=src python examples/cnn_kneaded.py
"""
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import cnn_layer_data, cnn_weights
from repro.core import cost_model, knead, quantize, sac_matmul
from repro.kernels.sac_matmul.ops import sac_matmul_pallas
from repro.models import cnn


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, pad)
    return jnp.pad(x, pads)


def kneaded_apply(params, x, cfg, bits=8, pallas_layer=None):
    """CNN forward with every matmul routed through SAC on kneaded weights."""
    flat = False
    for i, item in enumerate(cfg.spec):
        kind = item[0]
        if kind == "conv":
            _, out_c, k, stride = item
            patches = cnn._im2col(x, k, stride)
            p = params[f"conv{i}"]
            w = _pad_to(_pad_to(p["w"], 256, 0), 128, 1)
            kw = knead(w, bits=bits, ks=256)
            a2 = _pad_to(patches.reshape(-1, patches.shape[-1]), 256, 1)
            if pallas_layer == f"conv{i}":
                y = sac_matmul_pallas(a2, kw, bm=128)
            else:
                y = sac_matmul(a2, kw, impl="int")
            y = y[:, :p["w"].shape[1]].reshape(
                patches.shape[:-1] + (p["w"].shape[1],))
            x = jax.nn.relu(y + p["b"])
        elif kind == "pool":
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, item[1], item[1], 1),
                                      (1, item[1], item[1], 1), "VALID")
        elif kind == "fc":
            if not flat:
                x = x.reshape(x.shape[0], -1)
                flat = True
            p = params[f"fc{i}"]
            w = _pad_to(_pad_to(p["w"], 256, 0), 128, 1)
            kw = knead(w, bits=bits, ks=256)
            y = sac_matmul(_pad_to(x, 256, 1), kw,
                           impl="int")[:, :p["w"].shape[1]]
            x = y + p["b"]
            if i != len(cfg.spec) - 1:
                x = jax.nn.relu(x)
    return x


def main():
    cfg = cnn.CNN_ZOO["vgg16"]
    params = cnn_weights("vgg16")
    x = jax.random.normal(jax.random.PRNGKey(7),
                          (8, cfg.image_size, cfg.image_size, 3))

    ref = cnn.apply(params, x, cfg)
    out = kneaded_apply(params, x, cfg, bits=8, pallas_layer="conv3")
    agree = float(jnp.mean((jnp.argmax(out, -1) == jnp.argmax(ref, -1))
                           .astype(jnp.float32)))
    print(f"kneaded-int8 VGG-16: top-1 agreement with float = {100*agree:.0f}%"
          f"  (conv3 ran through the Pallas SAC kernel)")

    weights, acts = cnn_layer_data("vgg16")
    print(f"\n{'layer':>8} {'K x N':>14} {'kneaded/bf16':>13} {'tetris x':>9}")
    for name, w in list(weights.items())[:8]:
        w2 = _pad_to(_pad_to(jnp.asarray(w), 256, 0), 128, 1)
        kw = knead(w2, bits=8, ks=256)
        ratio = kw.packed_bytes() / kw.dense_bf16_bytes()
        qw = quantize(jnp.asarray(w), bits=16, axis=None)
        qa = quantize(jnp.abs(acts[name][:2048]), bits=16, axis=None)
        c = cost_model.model_layer(qw.q, qa.q, bits=16, ks=16)
        print(f"{name:>8} {str(tuple(w.shape)):>14} {ratio:13.3f} "
              f"{c.dadn/c.tetris:9.2f}")


if __name__ == "__main__":
    main()
