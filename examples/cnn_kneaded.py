"""The paper's own domain end to end: a kneaded VGG-16 classifier.

Trains VGG-16 briefly, hands the float checkpoint to ``CNNServingEngine``,
which converts EVERY conv/fc layer to the kneaded bit-plane format (the
Tetris deployment artifact) and runs the whole forward pass through SAC —
then demonstrates the Pallas kernel path end to end on an AlexNet-16 and
reports:

  * classification agreement between float and kneaded inference,
  * the per-layer kneaded HBM footprint vs bf16 + kneaded cycle ratio,
  * bit-exactness of the Pallas kernel against the planes oracle.

Run:  PYTHONPATH=src python examples/cnn_kneaded.py

``--devices N`` (N >= 2) forces N host CPU devices
(``XLA_FLAGS=--xla_force_host_platform_device_count``) and additionally
runs the *sharded* serving path (docs/DESIGN.md §5): every AlexNet-16
layer's compacted schedule is partitioned along its out-channel dimension
over an N-device "model" mesh, the SAC kernel launches once per device
under ``jax.shard_map``, and the demo prints per-shard executed work plus
bit-exactness against the unsharded kernel:

    PYTHONPATH=src python examples/cnn_kneaded.py --devices 4

(The flag must be parsed before jax imports, which is why the heavy imports
live inside ``main``.)
"""
import argparse
import os
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def parse_args():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=1,
                    help="force N host CPU devices and demo the sharded "
                         "serving path (default 1: single device)")
    return ap.parse_args()


def main(args):
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import cnn_weights
    from repro.inference.cnn_engine import CNNServingConfig, CNNServingEngine
    from repro.models import cnn

    cfg = cnn.CNN_ZOO["vgg16"]
    params = cnn_weights("vgg16")
    x = jax.random.normal(jax.random.PRNGKey(7),
                          (8, cfg.image_size, cfg.image_size, 3))

    float_eng = CNNServingEngine(cfg, params, CNNServingConfig(impl="float"))
    kneaded_eng = CNNServingEngine(cfg, params,
                                   CNNServingConfig(impl="int", bits=8))
    ref = float_eng.classify(x)
    pred = kneaded_eng.classify(x)
    agree = float(jnp.mean((pred == ref).astype(jnp.float32)))
    ratio = kneaded_eng.serving_bytes() / max(1, float_eng.serving_bytes())
    print(f"kneaded-int8 VGG-16: top-1 agreement with float = {100*agree:.0f}%"
          f"  (serving bytes = {ratio:.3f}x of bf16)")

    print(f"\n{'layer':>8} {'K x N':>14} {'kneaded/bf16':>13} {'cycles%':>8}")
    for row in kneaded_eng.layer_report(cycle_ks=16)[:8]:
        print(f"{row['layer']:>8} {str(row['shape']):>14} "
              f"{row['bytes_vs_bf16']:13.3f} {100*row['cycle_ratio']:8.1f}")

    # The Pallas kernel path, end to end (interpret mode on CPU): every
    # layer of an AlexNet-16 through the schedule-compacted SAC kernel —
    # one pallas_call per layer, dispatching only the occupied work items —
    # bit-exact against the paper-faithful planes decomposition.
    small = dataclasses.replace(cnn.CNN_ZOO["alexnet"], image_size=16)
    sparams = cnn.init(jax.random.PRNGKey(0), small)
    xs = jax.random.normal(jax.random.PRNGKey(8), (2, 16, 16, 3))
    lg = CNNServingEngine(small, sparams,
                          CNNServingConfig(impl="pallas", jit=False)).logits(xs)
    if args.devices == 1:
        lp = CNNServingEngine(small, sparams, CNNServingConfig(
            impl="planes", jit=False)).logits(xs)
        exact = bool(np.array_equal(np.asarray(lg), np.asarray(lp)))
        print(f"\nalexnet-16 fully through the Pallas SAC kernel: "
              f"bit-exact vs planes oracle = {exact}")
    else:
        # forcing host devices re-partitions XLA CPU threading, which
        # perturbs the dense jnp oracle's f32 reduction order (the Pallas
        # kernel is bit-stable) — the oracle comparison only means anything
        # on one device; see docs/DESIGN.md §5
        print("\n(planes-oracle comparison skipped under forced host "
              "devices; see docs/DESIGN.md §5)")

    if args.devices > 1:
        # Sharded serving (docs/DESIGN.md §5): one schedule shard — and one
        # kernel launch under shard_map — per forced host device.
        assert jax.device_count() >= args.devices, jax.device_count()
        sh = CNNServingEngine(small, sparams, CNNServingConfig(
            impl="pallas", jit=False, shards=args.devices))
        ls = sh.logits(xs)
        exact = bool(np.array_equal(np.asarray(ls), np.asarray(lg)))
        print(f"\nsharded over {args.devices} devices: bit-exact vs "
              f"single-device kernel = {exact}")
        print(f"{'layer':>8} {'per-shard executed work':>28} {'skew':>6}")
        for row in sh.layer_report():
            print(f"{row['layer']:>8} {str(row['shard_work']):>28} "
                  f"{row['shard_imbalance']:6.2f}")


if __name__ == "__main__":
    _args = parse_args()
    if _args.devices > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{_args.devices}").strip()
    main(_args)
