"""The paper's own domain end to end: a kneaded VGG-16 classifier.

Trains VGG-16 briefly, hands the float checkpoint to ``CNNServingEngine``,
which converts EVERY conv/fc layer to the kneaded bit-plane format (the
Tetris deployment artifact) and runs the whole forward pass through SAC —
then demonstrates the Pallas kernel path end to end on an AlexNet-16 and
reports:

  * classification agreement between float and kneaded inference,
  * the per-layer kneaded HBM footprint vs bf16 + kneaded cycle ratio,
  * bit-exactness of the Pallas kernel against the planes oracle.

Run:  PYTHONPATH=src python examples/cnn_kneaded.py
"""
import dataclasses
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import cnn_weights
from repro.inference.cnn_engine import CNNServingConfig, CNNServingEngine
from repro.models import cnn


def main():
    cfg = cnn.CNN_ZOO["vgg16"]
    params = cnn_weights("vgg16")
    x = jax.random.normal(jax.random.PRNGKey(7),
                          (8, cfg.image_size, cfg.image_size, 3))

    float_eng = CNNServingEngine(cfg, params, CNNServingConfig(impl="float"))
    kneaded_eng = CNNServingEngine(cfg, params,
                                   CNNServingConfig(impl="int", bits=8))
    ref = float_eng.classify(x)
    pred = kneaded_eng.classify(x)
    agree = float(jnp.mean((pred == ref).astype(jnp.float32)))
    ratio = kneaded_eng.serving_bytes() / max(1, float_eng.serving_bytes())
    print(f"kneaded-int8 VGG-16: top-1 agreement with float = {100*agree:.0f}%"
          f"  (serving bytes = {ratio:.3f}x of bf16)")

    print(f"\n{'layer':>8} {'K x N':>14} {'kneaded/bf16':>13} {'cycles%':>8}")
    for row in kneaded_eng.layer_report(cycle_ks=16)[:8]:
        print(f"{row['layer']:>8} {str(row['shape']):>14} "
              f"{row['bytes_vs_bf16']:13.3f} {100*row['cycle_ratio']:8.1f}")

    # The Pallas kernel path, end to end (interpret mode on CPU): every
    # layer of an AlexNet-16 through the schedule-compacted SAC kernel —
    # one pallas_call per layer, dispatching only the occupied work items —
    # bit-exact against the paper-faithful planes decomposition.
    small = dataclasses.replace(cnn.CNN_ZOO["alexnet"], image_size=16)
    sparams = cnn.init(jax.random.PRNGKey(0), small)
    xs = jax.random.normal(jax.random.PRNGKey(8), (2, 16, 16, 3))
    lg = CNNServingEngine(small, sparams,
                          CNNServingConfig(impl="pallas", jit=False)).logits(xs)
    lp = CNNServingEngine(small, sparams,
                          CNNServingConfig(impl="planes", jit=False)).logits(xs)
    exact = bool(np.array_equal(np.asarray(lg), np.asarray(lp)))
    print(f"\nalexnet-16 fully through the Pallas SAC kernel: "
          f"bit-exact vs planes oracle = {exact}")


if __name__ == "__main__":
    main()
