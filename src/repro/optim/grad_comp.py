"""int8 gradient compression with error feedback (distributed-opt trick).

At 1000+ nodes the cross-pod (DCN) gradient all-reduce is the scaling
bottleneck; int8 quantization cuts it 4x vs f32.  Error feedback keeps the
*accumulated* quantization error in the optimizer loop so convergence is
preserved (Seide et al. / EF-SGD family).

Mechanics: per-leaf symmetric int8 quantization of (grad + error_carry);
the de-quantized value is what the optimizer sees; the residual goes back
into the carry.  Under pjit the actual all-reduce happens on the int8-scaled
representation because compression is applied *before* the psum boundary in
``shard_map``-wrapped reduction (see ``compressed_psum``); in the plain
data-parallel train step the compression still bounds gradient-exchange
bytes because XLA reduces the int8-cast values.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def init_error_state(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads: PyTree,
                        error: Optional[PyTree]) -> Tuple[PyTree, PyTree]:
    """Returns (decompressed grads, new error carry)."""
    if error is None:
        error = init_error_state(grads)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize_leaf(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(one, grads, error,
                       is_leaf=lambda x: isinstance(x, jax.Array))
    deq = jax.tree.map(lambda o: o[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda o: o[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, err


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-quantized psum for use inside shard_map: quantize, reduce the
    int32-accumulated codes, rescale by the max scale across the group."""
    q, scale = _quantize_leaf(x)
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantize against the group-wide scale so codes are commensurable
    q2 = jnp.clip(jnp.round(x / scale_max), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q2, axis_name)
    return total.astype(jnp.float32) * scale_max
