"""AdamW with pluggable state dtype — built here (no optax dependency).

State shards exactly like the parameters (GSPMD propagates the in_shardings
of the train step), so full-Adam memory is params*(1 + 2*state_bytes/4)
per replica group — the FSDP axis divides it by |pod|*|data|.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    m: PyTree                # first moment  (opt dtype)
    v: PyTree                # second moment (opt dtype)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"     # "bfloat16" halves optimizer memory
    warmup_steps: int = 100
    total_steps: int = 10_000


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to 10%."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, s / max(cfg.warmup_steps, 1))
    frac = jnp.clip((s - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.55 + 0.45 * jnp.cos(jnp.pi * frac)
    return cfg.lr * warm * cos


def init(params: PyTree, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(z, params),
                      v=jax.tree.map(z, params))


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads: PyTree, state: AdamWState, params: PyTree,
           cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(cfg.state_dtype)

    def upd_core(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return ((p - lr * delta).astype(p.dtype),
                m32.astype(dt), v32.astype(dt))

    # NB: an lax.map-over-layers variant was tried to cap the f32 m32/v32
    # temporaries; it REGRESSED temp by ~10 GiB because scan boundaries
    # defeat donated-buffer aliasing (EXPERIMENTS.md §Perf, iteration log).
    out = jax.tree.map(upd_core, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
