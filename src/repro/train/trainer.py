"""The resilient training driver: data -> step -> checkpoint -> restart.

Wires together the substrate: SyntheticTokens (stateless data),
make_train_step (pjit'd update), AsyncCheckpointer (durable state),
fault_tolerance (restart + straggler watermarks).  Used by
examples/train_smollm.py and the integration tests; the same loop is what
launch.train runs on a real cluster (per-host data slices via
``host_batch``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax

from repro.checkpoint import checkpointer as ckpt
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.lm import LanguageModel
from repro.optim import adamw
from repro.runtime import fault_tolerance as ft
from repro.train.step import TrainStepConfig, make_train_step

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    log_every: int = 10
    restart: ft.RestartPolicy = dataclasses.field(
        default_factory=ft.RestartPolicy)


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 ts: Optional[TrainStepConfig] = None,
                 global_batch: int = 8, seq_len: int = 128,
                 injector: Optional[ft.FailureInjector] = None):
        self.cfg, self.tcfg = cfg, tcfg
        self.model = LanguageModel(cfg)
        self.ts = ts or TrainStepConfig()
        self.data = SyntheticTokens(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=seq_len,
            global_batch=global_batch, seed=tcfg.seed))
        self.step_fn = jax.jit(make_train_step(self.model, self.ts),
                               donate_argnums=(0, 1))
        self.ckpt = ckpt.AsyncCheckpointer(tcfg.ckpt_dir)
        self.timer = ft.StepTimer()
        self.injector = injector
        self.metrics_log: Dict[int, Dict[str, float]] = {}
        self.restarts = 0

        key = jax.random.PRNGKey(tcfg.seed)
        self.params = self.model.init(key)
        self.opt_state = adamw.init(self.params, self.ts.optimizer)
        self.ef_state = None
        self._step = 0

    # ------------------------------------------------------------- plumbing
    def _save(self, step: int, block: bool = False):
        self.ckpt.save(step, {"params": self.params,
                              "opt": self.opt_state}, block=block)

    def _restore(self) -> int:
        self.ckpt.wait()
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return 0
        like = {"params": self.params, "opt": self.opt_state}
        tree = ckpt.restore(self.tcfg.ckpt_dir, last, like)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self._step = last
        return last

    # ----------------------------------------------------------------- run
    def _one_step(self, step: int):
        self.timer.start()
        if self.injector is not None:
            self.injector.maybe_fail(step)
        batch = self.data.global_batch(step)
        self.params, self.opt_state, self.ef_state, metrics = self.step_fn(
            self.params, self.opt_state, batch, self.ef_state)
        dt = self.timer.stop(step)
        if step % self.tcfg.log_every == 0 or step == self.tcfg.num_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step_time_s"] = dt
            self.metrics_log[step] = m
        if step and step % self.tcfg.ckpt_every == 0:
            self._save(step)
        self._step = step + 1

    def run(self) -> Dict[int, Dict[str, float]]:
        start = self._restore() if ckpt.latest_step(
            self.tcfg.ckpt_dir) is not None else 0
        self.restarts = ft.run_resilient_loop(
            start_step=start, num_steps=self.tcfg.num_steps,
            step_fn=self._one_step, restore_fn=self._restore,
            policy=self.tcfg.restart)
        self._save(self.tcfg.num_steps - 1, block=True)
        return self.metrics_log
