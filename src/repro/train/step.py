"""Train / serve step factories.

``make_train_step`` builds the pjit-able update:
  * gradient accumulation over microbatches (lax.scan) — one deferred
    all-reduce worth of gradient traffic per step, overlapping microbatch
    compute with the FSDP gathers of the next layer (XLA latency hiding);
  * optional int8 gradient compression with error feedback (optim.grad_comp);
  * AdamW update with configurable state dtype.

``make_prefill_step`` / ``make_decode_step`` build the serving steps; both
accept float or kneaded (quantized) parameter trees — the Tetris serving
path substitutes QuantizedTensor / PackedInt4 leaves and everything below
dispatches through ``matmul_any``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.lm import LanguageModel
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig, AdamWState

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatch: int = 0            # 0 => single batch, no accumulation
    grad_compression: str = "none"  # none | int8_ef (see optim.grad_comp)
    grad_dtype: str = "float32"


def _cast_floats(tree, dtype, shardings=None):
    def one(x, sh=None):
        if not (hasattr(x, "dtype") and jnp.issubdtype(x.dtype,
                                                       jnp.floating)):
            return x
        y = x.astype(dtype)
        # Pin the cast output's SHARDING: sharding propagation otherwise
        # marks the convert replicated (from the consuming dot), which
        # moves the FSDP all-gather above the convert — i.e. the gather
        # moves f32 master weights (measured 2x collective traffic).
        if sh is not None:
            y = jax.lax.with_sharding_constraint(y, sh)
        return y
    if shardings is None:
        return jax.tree.map(one, tree)
    return jax.tree.map(one, tree, shardings)


def make_train_step(model: LanguageModel, ts: TrainStepConfig,
                    param_shardings: Optional[Any] = None):
    cfg = model.cfg

    def loss_fn(params, batch):
        # Cast the WHOLE param tree to bf16 once, before the layer scan.
        # With f32 masters entering the scan, every FSDP all-gather and
        # every TP partial-sum all-reduce moves f32 (measured: the top-10
        # collectives on llama3 train were all f32) — casting here makes
        # the per-layer collectives bf16 (2x traffic cut) and turns the
        # f32 conversion into one elementwise op per step.  Gradients
        # arrive as bf16 cotangents and convert to f32 exactly once at
        # this cast's transpose.
        return model.loss(
            _cast_floats(params, jnp.bfloat16, param_shardings), batch)

    def train_step(params, opt_state: AdamWState, batch, ef_state=None):
        """batch: dict of [B_global, ...] arrays.  Returns
        (params, opt_state, ef_state, metrics)."""
        mb = ts.microbatch
        b = batch["tokens"].shape[0]
        gdt = jnp.dtype(ts.grad_dtype)
        if mb and mb < b:
            assert b % mb == 0, (b, mb)
            n = b // mb
            split = jax.tree.map(
                lambda x: x.reshape((n, mb) + x.shape[1:]), batch)

            def acc_body(carry, micro):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, micro)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(gdt) / n, g_acc, g)
                return (g_acc, l_acc + l / n), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
            (grads, loss), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), split)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        if ts.grad_compression == "int8_ef":
            from repro.optim import grad_comp
            grads, ef_state = grad_comp.compress_decompress(grads, ef_state)

        params, opt_state, metrics = adamw.update(
            grads, opt_state, params, ts.optimizer)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, ef_state, metrics

    return train_step


def make_eval_step(model: LanguageModel):
    def eval_step(params, batch):
        return model.loss(params, batch)
    return eval_step


def make_prefill_step(model: LanguageModel):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: LanguageModel):
    def decode_step(params, token, pos, cache):
        return model.decode_step(params, token, pos, cache)
    return decode_step
