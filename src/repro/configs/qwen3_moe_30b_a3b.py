"""qwen3-moe-30b-a3b [moe] — hf:Qwen/Qwen3-30B-A3B.

48 layers, d_model=2048, 32 heads (GQA kv=4), per-expert d_ff=768,
vocab=151936, MoE 128 experts top-8, qk-norm (Qwen3 signature).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,            # Qwen3 uses decoupled head_dim=128
    d_ff=0,                  # no dense FFN — pure MoE layers
    moe_dff=768,
    vocab_size=151936,
    num_experts=128,
    top_k=8,
    qk_norm=True,
    sequence_parallel=True,
    sp_matmul_gather=False,
    activation="swiglu",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, moe_dff=64, vocab_size=512, num_experts=8, top_k=2,
    attn_chunk=64,
)
