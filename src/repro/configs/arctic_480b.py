"""arctic-480b [moe] — hf:Snowflake/snowflake-arctic-base.

35 layers, d_model=7168, 56 heads (GQA kv=8), d_ff=4864, vocab=32000,
MoE 128 experts top-2 **plus a dense residual FFN in parallel** (Arctic's
dense-MoE hybrid design).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    top_k=2,
    moe_dff=4864,
    dense_residual=True,
    activation="swiglu",
    sequence_parallel=True,
    sp_matmul_gather=False,
    flash_replicate_pin=False,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=512, num_experts=8, top_k=2, moe_dff=64, attn_chunk=64,
)
