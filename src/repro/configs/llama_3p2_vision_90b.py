"""llama-3.2-vision-90b [vlm] — hf:meta-llama/Llama-3.2-11B-Vision (90B cfg).

100 layers, d_model=8192, 64 heads (GQA kv=8), d_ff=28672, vocab=128256.
One gated cross-attention layer per 5 layers (20 cross-attn applications).
The vision encoder is a STUB per the assignment: ``input_specs`` feeds
precomputed patch embeddings [B, num_image_tokens, d_model].
num_image_tokens=2048 (≈4 image tiles; rounded to the MXU tile — the
frontend is a stub so only the shape matters, recorded in docs/DESIGN.md §6).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    num_image_tokens=2048,
    rope_theta=500_000.0,
    sequence_parallel=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=512, cross_attn_every=2, num_image_tokens=16, attn_chunk=64,
)
