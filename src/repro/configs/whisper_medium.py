"""whisper-medium [audio enc-dec] — arXiv:2212.04356.

24 encoder + 24 decoder layers, d_model=1024, 16 heads (kv=16), d_ff=4096,
vocab=51865.  The conv audio frontend is a STUB per the assignment:
``input_specs`` feeds precomputed frame embeddings [B, encoder_seq, d_model].
encoder_seq is 1536 (real Whisper: 1500 mel frames -> we round up to the
512-lane tile for MXU alignment; frontend is a stub so only the shape
matters — recorded in docs/DESIGN.md §6).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    activation="gelu",
    norm="layernorm",
    positional="learned",
    encoder_seq=1536,
    parallelism="dp",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=512, encoder_seq=16, attn_chunk=64,
)
