"""phi3-medium-14b [dense] — arXiv:2404.14219.

40 layers, d_model=5120, 40 heads (GQA kv=10), d_ff=17920, vocab=100352,
RoPE + SwiGLU + GQA.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    parallelism="dp",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=512, attn_chunk=64,
)
