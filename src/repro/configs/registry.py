"""Architecture registry: ``--arch <id>`` resolution for all launchers.

Maps arch ids to (CONFIG, SMOKE) plus the per-arch shape applicability rules
from docs/DESIGN.md §4 (long_500k skipped for pure full-attention archs).
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import InputShape, LM_SHAPES, ModelConfig

_MODULES: Dict[str, str] = {
    "whisper-medium": "repro.configs.whisper_medium",
    "arctic-480b": "repro.configs.arctic_480b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "xlstm-1.3b": "repro.configs.xlstm_1p3b",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "llama3-8b": "repro.configs.llama3_8b",
    "smollm-360m": "repro.configs.smollm_360m",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "llama-3.2-vision-90b": "repro.configs.llama_3p2_vision_90b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.SMOKE if smoke else mod.CONFIG


def applicable_shapes(cfg: ModelConfig) -> List[InputShape]:
    """The shape cells this arch runs (docs/DESIGN.md §4).

    long_500k requires sub-quadratic context handling -> only SSM/hybrid
    families run it; pure full-attention archs record the cell as skipped.
    """
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
            continue
        out.append(s)
    return out


def all_cells(smoke: bool = False) -> List[Tuple[str, InputShape]]:
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=smoke)
        for s in applicable_shapes(cfg):
            cells.append((arch, s))
    return cells
