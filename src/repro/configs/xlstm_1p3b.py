"""xlstm-1.3b [ssm] — arXiv:2405.04517.

48 blocks, d_model=2048, 4 heads, vocab=50304, d_ff=0 (xLSTM blocks carry
their own up/down projections, expand factor 2).  Block ratio 7 mLSTM : 1
sLSTM (the paper's xLSTM[7:1]) -> groups of 8.  Runs long_500k: state is
O(1) in context (matrix memories), no KV cache growth.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm_expand=2,
    slstm_every=8,
    positional="none",
    parallelism="dp",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=4, d_model=64, num_heads=2, num_kv_heads=2,
    vocab_size=512, slstm_every=2, attn_chunk=64,
)
