"""Config schema: ModelConfig (architecture) + InputShape (workload cell).

Every assigned architecture provides a module ``repro.configs.<id>`` exposing
``CONFIG`` (the exact published config) and ``SMOKE`` (a reduced same-family
config runnable on one CPU).  ``repro.configs.registry`` maps ids to both.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

__all__ = ["ModelConfig", "InputShape", "LM_SHAPES", "shape_by_name"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = "model"
    family: str = "dense"   # dense | moe | ssm | hybrid | encdec | vlm
    # trunk
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int = 0                 # 0 -> d_model // num_heads
    activation: str = "swiglu"        # swiglu | gelu | relu2
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    positional: str = "rope"          # rope | learned | none
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    qk_norm: bool = False             # qwen3-style per-head q/k RMSNorm
    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0
    dense_residual: bool = False      # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    attn_every: int = 0               # zamba2: shared attn block every N blocks
    slstm_every: int = 0              # xlstm: one sLSTM per this many blocks
    # enc-dec
    encoder_layers: int = 0
    encoder_seq: int = 0              # whisper: 1500 precomputed frames (stub)
    # vlm
    cross_attn_every: int = 0         # one cross-attn layer per this many
    num_image_tokens: int = 0         # precomputed patch embeddings (stub)
    # parallelism profile: "tp" = FSDP+TP(+EP) (Megatron-style; required for
    # the 90B+ and MoE archs); "dp" = ZeRO-3-style pure data parallel with
    # fully-sharded params (no TP activation all-reduces) — the right choice
    # for <=30B dense/ssm archs on 256+ chips (EXPERIMENTS.md §Perf it.2).
    # Applies to train cells; serving always uses "tp".
    parallelism: str = "tp"
    # numerics / execution
    dtype: str = "bfloat16"
    remat: bool = True
    sequence_parallel: bool = False   # shard residual activations over model
    attn_chunk: int = 1024            # blockwise-attention chunk (prefill/train)
    attn_impl: str = "flash"          # flash | masked (see §Perf)
    # pin batch-only (replicated-head) layout on flash-loop tensors when
    # kv_heads doesn't divide TP: big win for deep/microbatched archs
    # (nemotron 3x), slightly negative for arctic (no microbatching) — §Perf
    flash_replicate_pin: bool = True
    # explicit Megatron-SP activation gather before TP matmuls: required for
    # big-dense archs (nemotron: stops full-weight gathers, 4x), harmful for
    # the MoE archs whose shard_map/flash layouts reshard better unaided
    sp_matmul_gather: bool = True
    # int8 KV cache (dense/moe families): kneads the *cache* the same way
    # weights are kneaded — per-(position, head) scale, 2x decode cache bytes
    kv_cache_bits: int = 0            # 0 = bf16, 8 = int8
    # SAC execution path for KneadedWeight projection leaves (the kneaded
    # LM serving form, docs/DESIGN.md §7): "float" | "int" | "planes" |
    # "pallas".  Float-weight leaves ignore it, so training configs can
    # leave the default; ServingEngine overrides it to match its impl.
    # (Canonical name ``impl`` — the same switch the serving configs use;
    # ``sac_impl=`` is accepted as a deprecated constructor/replace alias,
    # consumed by __post_init__ and normalized back to None so a later
    # ``dataclasses.replace(cfg, impl=...)`` can never be overridden by a
    # stale copied alias.  Read sites must use ``cfg.impl``.)
    impl: str = "int"
    sac_impl: Optional[str] = dataclasses.field(default=None, repr=False,
                                                compare=False)
    # Runtime activation-side skip for KneadedWeight leaves (two-sided skip,
    # docs/DESIGN.md §12): intersect per-K-tile activation presence into the
    # kernel's schedule walk on decode-GEMV calls (<= 8 flattened rows);
    # prefill falls back to the static weight-only skip.  Bit-exact on/off —
    # dropped work items contribute exactly 0.0.  Float-weight leaves and
    # the non-pallas impls ignore it; ServingEngine overrides it from
    # ``ServingConfig.activation_skip``.
    activation_skip: bool = False
    window: int = 0                   # >0: sliding-window attention (long ctx)
    # training
    microbatch: int = 0               # 0 -> no gradient accumulation

    def __post_init__(self) -> None:
        if self.sac_impl is not None:
            warnings.warn(
                "ModelConfig.sac_impl is deprecated; use impl=",
                DeprecationWarning, stacklevel=3)
            object.__setattr__(self, "impl", self.sac_impl)
            object.__setattr__(self, "sac_impl", None)
        if self.num_experts:
            if not (0 < self.top_k <= self.num_experts):
                raise ValueError(
                    f"{self.name!r}: top_k={self.top_k} must be in "
                    f"[1, num_experts={self.num_experts}]")
            if self.moe_dff <= 0 and self.d_ff <= 0:
                raise ValueError(
                    f"{self.name!r}: MoE config needs moe_dff (or d_ff) > 0")
            if self.capacity_factor <= 0:
                raise ValueError(
                    f"{self.name!r}: capacity_factor must be > 0, "
                    f"got {self.capacity_factor}")

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def full_attention(self) -> bool:
        """True if the arch relies on (windowless) softmax attention."""
        return self.family not in ("ssm",) and self.window == 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + trunk), for 6ND roofline."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab_size, self.hd
        nh, nkv = self.num_heads, self.num_kv_heads
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        if self.activation == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        per_layer = attn + (mlp if f else 0)
        if self.num_experts:
            e_f = self.moe_dff or f
            moe = self.num_experts * 3 * d * e_f + d * self.num_experts
            per_layer = attn + moe + (mlp if self.dense_residual and f else 0)
        if self.family == "ssm":
            di = self.ssm_expand * d
            per_layer = 2 * (d * 2 * di + di * d)     # coarse mLSTM/sLSTM proj
        if self.family == "hybrid":
            di = self.ssm_expand * d
            per_layer = d * 2 * di + di * d + 2 * di * self.ssm_state
        n = self.num_layers * per_layer + v * d * (1 if self.tie_embeddings else 2)
        if self.encoder_layers:
            n += self.encoder_layers * (attn + (mlp if f else 0))
        if self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            n += n_cross * (attn)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        e_f = self.moe_dff or f
        dense = self.param_count() - self.num_layers * self.num_experts * 3 * d * e_f
        return dense + self.num_layers * self.top_k * 3 * d * e_f


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One workload cell: (kind, seq_len, global_batch)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


LM_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> InputShape:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
