"""smollm-360m [dense] — hf:HuggingFaceTB/SmolLM-360M.

32 layers, d_model=960, 15 heads (GQA kv=5), d_ff=2560, vocab=49152,
tied embeddings (llama-arch small).  This is the end-to-end training
example arch (examples/train_smollm.py).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
    parallelism="dp",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=48, num_heads=3, num_kv_heads=1, d_ff=128,
    vocab_size=512, attn_chunk=64,
)
