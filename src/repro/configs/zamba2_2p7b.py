"""zamba2-2.7b [hybrid] — arXiv:2411.15242.

54 Mamba2 blocks (d_model=2560, ssm_state=64) with a *shared* attention +
MLP block (32 heads, kv=32, d_ff=10240) applied every 6 blocks (9
applications, one weight set — Zamba2's parameter-sharing design).
Runs long_500k: the trunk is SSM-dominated; decode attention over the shared
block's KV is linear in context.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    activation="gelu",
    parallelism="dp",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=512, ssm_state=16, ssm_head_dim=16, attn_every=2,
    attn_chunk=64,
)
