"""nemotron-4-340b [dense] — arXiv:2402.16819.

96 layers, d_model=18432, 96 heads (GQA kv=8), d_ff=73728, vocab=256000,
squared-ReLU MLP (non-gated), LayerNorm, RoPE.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    activation="relu2",
    norm="layernorm",
    sequence_parallel=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=256,
    vocab_size=512, attn_chunk=64,
)
