"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device.

Production topology (TPU v5e):
  single-pod : 16 x 16  = 256 chips, axes ("data", "model")
  multi-pod  : 2 x 16 x 16 = 512 chips, axes ("pod", "data", "model")
The "pod" axis carries data parallelism across pods (gradient all-reduce
over DCN) and optionally pipeline stages (runtime.pipeline).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist right now, as a 1-D 'data' mesh (examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_model_mesh(num_devices: int | None = None):
    """The first ``num_devices`` devices as a 1-D "model" mesh.

    The sharded kneaded CNN serving mesh (docs/DESIGN.md §5): out-channel
    (N) shards of every layer's compacted schedule live one per device on
    this axis.  ``None`` takes every visible device; on CPU force more with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    import numpy as np
    devs = jax.devices()
    if num_devices is not None:
        if num_devices > len(devs):
            raise ValueError(f"requested {num_devices} devices, "
                             f"only {len(devs)} visible")
        devs = devs[:num_devices]
    return jax.sharding.Mesh(np.asarray(devs), ("model",))


def make_serving_mesh(model_shards: int = 1, *, expert_shards: int = 1):
    """The kneaded serving mesh (docs/DESIGN.md §8, §13).

    ``expert_shards <= 1`` keeps the historical 1-D ("model",) mesh —
    N-shards of every compacted schedule, one per device.  With
    ``expert_shards > 1`` the mesh becomes 2-D ("expert", "model") over the
    first ``expert_shards * model_shards`` devices: kneaded MoE expert
    banks shard whole experts on "expert" while the dense projections'
    N-shards stay on "model" (each axis replicates over the other).
    """
    if expert_shards <= 1:
        return make_model_mesh(model_shards)
    import numpy as np
    need = expert_shards * model_shards
    devs = jax.devices()
    if need > len(devs):
        raise ValueError(f"requested {expert_shards}x{model_shards} devices, "
                         f"only {len(devs)} visible")
    arr = np.asarray(devs[:need]).reshape(expert_shards, model_shards)
    return jax.sharding.Mesh(arr, ("expert", "model"))


# v5e hardware constants used by the roofline analysis (benchmarks/roofline).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
