import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory / cost / roofline inputs.

The two lines above MUST stay the first statements of this module (before
any jax import): jax locks the device count at first backend init, and the
dry-run needs 512 placeholder host devices to build the 2x16x16 mesh.
Nothing is allocated — all inputs are ShapeDtypeStructs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
  PYTHONPATH=src python -m repro.launch.dryrun --arch arctic-480b \
      --shape decode_32k --quant int8

Artifacts: benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>[__<quant>].json
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs.base import shape_by_name
from repro.configs.registry import ARCH_IDS, all_cells
from repro.launch import mesh as mesh_lib
from repro.launch.specs import build_cell
from repro.runtime import hlo_analysis, pspec

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / \
    "benchmarks" / "artifacts" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             quant=None, attn_impl=None, kv_bits=0, save=True,
             verbose=True) -> dict:
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    shape = shape_by_name(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}" + \
        (f"__{quant}" if quant else "") + \
        (f"__{attn_impl}" if attn_impl else "") + \
        (f"__kv{kv_bits}" if kv_bits else "")
    t0 = time.time()
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "quant": quant or "bf16", "attn_impl": attn_impl,
              "chips": mesh.devices.size}
    try:
        step, args, donate, meta = build_cell(
            arch, shape, mesh, quant=quant, attn_impl=attn_impl,
            kv_bits=kv_bits)
        result.update(meta)
        rules = None
        if meta.get("parallelism") == "dp":
            from repro.runtime import sharding as shd
            rules = {"batch": shd.dp_batch_axes(mesh, shape.global_batch),
                     "seq": (), "model": (), "expert": ()}
        with pspec.axis_rules(mesh, rules):
            lowered = jax.jit(step, donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        stats = hlo_analysis.analyze_hlo(hlo)
        hbm_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        terms = hlo_analysis.roofline_terms(
            stats, chips=mesh.devices.size,
            peak_flops=mesh_lib.PEAK_FLOPS_BF16,
            hbm_bw=mesh_lib.HBM_BW, ici_bw=mesh_lib.ICI_BW,
            hbm_bytes=max(hbm_bytes, 0))
        result.update({
            "ok": True,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            },
            "xla_cost_flops_per_iter": cost.get("flops", -1.0),
            "hbm_bytes_per_device": max(hbm_bytes, 0),
            "hlo_per_device": {
                "dot_flops": stats.dot_flops,
                "dot_bytes": stats.dot_bytes,
                "collective_bytes": stats.collective_bytes,
                "total_collective_bytes": stats.total_collective_bytes,
            },
            "roofline_terms_s": terms,
            "dominant_term": max(terms, key=terms.get),
        })
        if verbose:
            print(f"[OK] {tag}: lower {t_lower:.0f}s compile {t_compile:.0f}s "
                  f"args {mem.argument_size_in_bytes/2**30:.2f}GiB "
                  f"temp {mem.temp_size_in_bytes/2**30:.2f}GiB "
                  f"dot_flops/dev {stats.dot_flops:.3e} "
                  f"coll/dev {stats.total_collective_bytes:.3e}B "
                  f"dominant {result['dominant_term']}")
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug, record it
        result.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]})
        if verbose:
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
    if save:
        ART_DIR.mkdir(parents=True, exist_ok=True)
        (ART_DIR / f"{tag}.json").write_text(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--quant", choices=["bf16", "int8", "int4"], default=None)
    ap.add_argument("--attn-impl", dest="attn_impl", default=None,
                    choices=["masked", "flash"])
    ap.add_argument("--kv-bits", dest="kv_bits", type=int, default=0,
                    choices=[0, 8])
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, shape_by_name(args.shape))]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            r = run_cell(arch, shape.name, mp, quant=args.quant,
                         attn_impl=args.attn_impl, kv_bits=args.kv_bits)
            failures += 0 if r["ok"] else 1
    print(f"dry-run complete: {len(cells) * len(meshes) - failures} ok, "
          f"{failures} failed")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
