"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On a real cluster each host runs this entrypoint (jax.distributed
initializes from the TPU pod metadata); in this container it runs the smoke
config on the host devices.  The production mesh shape and sharding rules
are identical in both cases — only the device count differs.

XLA flags for collective/compute overlap on TPU are set here (latency-hiding
scheduler + async collectives), part of the distributed-optimization story.
"""
from __future__ import annotations

import argparse
import os

# Compute/communication overlap knobs (no-ops on CPU, required on TPU pods).
_TPU_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true "
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    args = ap.parse_args()

    if os.environ.get("TPU_NAME"):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") + " " + _TPU_FLAGS)

    from repro.configs.registry import get_config
    from repro.optim.adamw import AdamWConfig
    from repro.train.step import TrainStepConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch, smoke=args.smoke)
    ts = TrainStepConfig(
        optimizer=AdamWConfig(total_steps=args.steps),
        microbatch=args.microbatch,
        grad_compression=args.grad_compression)
    tr = Trainer(cfg, TrainerConfig(num_steps=args.steps,
                                    ckpt_every=args.ckpt_every,
                                    ckpt_dir=args.ckpt_dir),
                 ts=ts, global_batch=args.global_batch,
                 seq_len=args.seq_len)
    log = tr.run()
    for s, m in sorted(log.items()):
        print(f"step {s:6d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.3f}  {m['step_time_s']*1e3:.1f} ms")
    if tr.timer.straggler_steps:
        print("straggler steps:", tr.timer.straggler_steps)


if __name__ == "__main__":
    main()
