"""Serving launcher: batched generation with optional kneaded weights.

``python -m repro.launch.serve --arch smollm-360m --quant 8 --tokens 32``
trains nothing: initializes (or restores) params, kneads them to the
requested precision, and serves a batch of synthetic prompts — the
end-to-end demonstration of the paper's technique as a serving feature.
``--impl pallas`` serves through the fully-kneaded bit-plane path (the SAC
kernel's decode-GEMV fast path, docs/DESIGN.md §7); the default "quant"
keeps the integer-matmul form selected by ``--quant``.  ``--shards N``
partitions every kneaded projection's compacted schedule over an N-device
"model" mesh (docs/DESIGN.md §8; on CPU force devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* launch).

``--scheduler continuous`` routes the same prompts through the request
front end's continuous-batching slot scheduler (docs/DESIGN.md §9) with
``--max-inflight`` in-flight slots; ``--stream`` prints the first request's
tokens as they decode.  Both schedulers print the queue-wait vs decode-time
latency breakdown (p50/p95) from ``latency_stats()`` so they are directly
comparable from the CLI.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--quant", type=int, default=0, choices=[0, 8, 4])
    ap.add_argument("--impl", default="quant",
                    choices=["quant", "float", "int", "planes", "pallas"],
                    help="serving path: quantized matmuls (quant) or the "
                         "kneaded SAC forms (int/planes/pallas)")
    ap.add_argument("--knead-min-dim", type=int, default=128,
                    help="skip kneading projections smaller than this "
                         "(lower it for smoke-size archs)")
    ap.add_argument("--shards", type=int, default=0,
                    help="shard kneaded schedules over this many 'model'-"
                         "mesh devices (requires --impl pallas)")
    ap.add_argument("--expert-shards", type=int, default=0,
                    help="shard kneaded MoE expert banks over this many "
                         "'expert'-mesh devices (whole experts per device; "
                         "composes with --shards into a 2-D "
                         "('expert','model') mesh; requires a kneaded impl "
                         "and num_experts %% expert_shards == 0; "
                         "docs/DESIGN.md §13)")
    ap.add_argument("--shard-partition", default="contiguous",
                    choices=["contiguous", "balanced"],
                    help="tile→shard partitioning of sharded schedules: "
                         "contiguous N-tile slabs, or occupancy-balanced "
                         "LPT bin-packing with a recorded permutation "
                         "(bit-exact either way; docs/DESIGN.md §11)")
    ap.add_argument("--activation-skip", action="store_true",
                    help="arm the runtime activation-side skip (two-sided "
                         "skip, docs/DESIGN.md §12): per-K-tile presence "
                         "bits from the decode activation row are "
                         "intersected into every kneaded projection's "
                         "schedule walk, so work items whose activation "
                         "slice is all zero never execute.  Decode-GEMV "
                         "steps only (prefill keeps the static weight-only "
                         "skip); bit-exact on/off.  Effective with the "
                         "kneaded impls (int/planes/pallas); reports "
                         "act_skip_frac in the latency stats")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from a training checkpoint dir")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--scheduler", default="batch",
                    choices=["batch", "continuous"],
                    help="request scheduler: wave-synchronous padding-"
                         "bucket drain (batch) or the step-level slot "
                         "scheduler with a paged KV pool (continuous)")
    ap.add_argument("--max-inflight", type=int, default=8,
                    help="continuous scheduler: in-flight slot capacity")
    ap.add_argument("--stream", action="store_true",
                    help="print the first request's tokens as they decode")
    # resilience knobs (docs/DESIGN.md §10) — any of them arms the fault
    # policy: bounded retries + NaN quarantine + step watchdog + demotion
    ap.add_argument("--max-retries", type=int, default=None,
                    help="arm the fault policy: per-request recovery "
                         "attempts before the terminal FAILED state")
    ap.add_argument("--step-timeout", type=float, default=None,
                    help="watchdog threshold in seconds on one decode "
                         "launch (counts watchdog_timeouts in stats)")
    ap.add_argument("--fallback-impl", default=None,
                    help="comma-separated degradation ladder, strongest "
                         "first (default 'planes,float'): repeated step "
                         "faults demote --impl down this ladder")
    args = ap.parse_args()

    import jax

    from repro.checkpoint import checkpointer as ckpt
    from repro.configs.registry import get_config
    from repro.inference.engine import (ServingConfig, ServingEngine,
                                        serving_bytes)
    from repro.models.lm import LanguageModel

    cfg = get_config(args.arch, smoke=True)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        step = ckpt.latest_step(args.ckpt_dir)
        if step is not None:
            from repro.optim import adamw
            from repro.train.step import TrainStepConfig
            like = {"params": params,
                    "opt": adamw.init(params,
                                      TrainStepConfig().optimizer)}
            params = ckpt.restore(args.ckpt_dir, step, like)["params"]
            print(f"restored step {step} from {args.ckpt_dir}")

    fault_policy = None
    if (args.max_retries is not None or args.step_timeout is not None
            or args.fallback_impl is not None):
        from repro.inference.resilience import ServingFaultPolicy
        fault_policy = ServingFaultPolicy(
            max_retries=(args.max_retries if args.max_retries is not None
                         else 2),
            step_timeout_s=args.step_timeout or 0.0,
            fallback_impls=(tuple(args.fallback_impl.split(","))
                            if args.fallback_impl
                            else ("planes", "float")),
            verify_weights=bool(args.ckpt_dir))

    eng = ServingEngine(cfg, params, ServingConfig(
        max_len=args.prompt_len + args.tokens + 8,
        quant_bits=args.quant, temperature=args.temperature,
        impl=args.impl, knead_min_dim=args.knead_min_dim,
        shards=args.shards, shard_partition=args.shard_partition,
        expert_shards=args.expert_shards,
        activation_skip=args.activation_skip,
        scheduler=args.scheduler,
        max_inflight=args.max_inflight, fault_policy=fault_policy))
    if args.impl in ("int", "planes", "pallas"):
        precision = f"kneaded int{args.quant or 8}"   # engine default: 8
    elif args.impl == "float":
        precision = "bf16"
    else:
        precision = f"int{args.quant}" if args.quant else "bf16"
    shard_note = f", {args.shards}-way model mesh" if args.shards > 1 else ""
    if args.expert_shards > 1:
        shard_note += f", {args.expert_shards}-way expert mesh"
    print(f"serving params: {serving_bytes(eng.params)/1e6:.2f} MB "
          f"(impl={args.impl}, {precision}{shard_note})")
    work = eng.expert_work_table()
    for path, table in work.items():
        per_e = table.sum(axis=tuple(range(table.ndim - 1)))
        imb = float(per_e.max() / max(per_e.mean(), 1e-9))
        print(f"expert work {path}: per-expert tile-dots "
              f"{per_e.tolist()} (imbalance {imb:.2f}x)")

    key = jax.random.PRNGKey(7)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (args.batch, cfg.num_image_tokens, cfg.d_model))

    t0 = time.perf_counter()
    if cfg.family in ("encdec", "vlm") or (args.scheduler == "batch"
                                           and not args.stream):
        out = eng.generate(batch, args.tokens)
        rows = [r.tolist() for r in out[:2]]
    else:
        # route through the request front end so the scheduler choice
        # (and per-request stats) actually exercises
        handles = [eng.submit(prompts[i], args.tokens)
                   for i in range(args.batch)]
        if args.stream:
            print("streaming request 0:", end=" ", flush=True)
            for tok in handles[0].stream():
                print(tok, end=" ", flush=True)
            print()
        eng.drain()
        rows = [h.result().tolist() for h in handles[:2]]
    dt = time.perf_counter() - t0
    print(f"generated [{args.batch} x {args.tokens}] in {dt:.2f}s "
          f"({args.batch*args.tokens/dt:.1f} tok/s, "
          f"scheduler={args.scheduler})")
    for row in rows:
        print("  ", row)
    stats = eng.latency_stats()
    if stats["requests"]:
        print(f"latency p50/p95: {stats['p50_ms']:.1f}/"
              f"{stats['p95_ms']:.1f} ms over {stats['requests']} requests")
        if "queue_wait_p50_ms" in stats:
            print(f"  queue wait p50/p95: {stats['queue_wait_p50_ms']:.1f}/"
                  f"{stats['queue_wait_p95_ms']:.1f} ms | decode p50/p95: "
                  f"{stats['decode_p50_ms']:.1f}/"
                  f"{stats['decode_p95_ms']:.1f} ms")
    if "routed_tokens" in stats:
        print(f"routing: {stats['routed_tokens']} tokens routed over "
              f"{stats['routing_steps']} steps, "
              f"{stats['capacity_dropped']} dropped at capacity")
    if args.activation_skip and "act_skip_frac" in stats:
        print(f"activation skip: {stats['executed_tile_dots']} of "
              f"{stats['weight_tile_dots']} scheduled tile-dots executed "
              f"(act_skip_frac={stats['act_skip_frac']:.3f})")
    if fault_policy is not None:
        fault_keys = ("retries", "failed_requests", "recoveries",
                      "nan_quarantined", "watchdog_timeouts",
                      "straggler_steps", "degradations",
                      "integrity_repairs")
        counters = {k: stats[k] for k in fault_keys if k in stats}
        print(f"fault counters: {counters or 'clean'} "
              f"(impl now {eng.scfg.impl})")


if __name__ == "__main__":
    main()
