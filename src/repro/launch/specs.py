"""Input ShapeDtypeStruct stand-ins + sharded step assembly per cell.

``build_cell(arch, shape, mesh, ...)`` returns (step_fn, args) where every
arg is a ShapeDtypeStruct carrying its NamedSharding — ready for
``jax.jit(step_fn).lower(*args)``.  Nothing is ever allocated.

The Tetris serving modes substitute weight leaves:
  quant="int8" -> QuantizedTensor codes (1 B/weight in HBM)
  quant="int4" -> PackedInt4 nibbles   (0.5 B/weight)
both with per-channel f32 scales — the kneaded decode path whose memory-
roofline gain §Perf quantifies against the bf16 baseline.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.configs.registry import get_config
from repro.core.quantization import QuantizedTensor
from repro.models.layers import PackedInt4
from repro.models.lm import LanguageModel
from repro.optim import adamw
from repro.runtime import sharding
from repro.train.step import TrainStepConfig, make_train_step

PyTree = Any

# weight-name suffixes eligible for kneading — single definition shared
# with inference.engine.knead_params lives beside the kneader itself;
# embeddings stay bf16 (gather path), norms/gates are not matmuls.
from repro.core.kneading import KNEADABLE_NAMES as _KNEADABLE


def _sds(shape, dtype, mesh: Mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def param_sds(model: LanguageModel, mesh: Mesh, mode: str = "tp") -> PyTree:
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    shardings = sharding.tree_shardings(shapes, mesh, mode)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def quantize_param_sds(params: PyTree, quant: str) -> PyTree:
    """Replace kneadable 2-D weight SDS leaves with quantized containers."""
    if quant in (None, "bf16", "none"):
        return params
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        keys = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        name = keys[-1] if keys else ""
        shp = leaf.shape
        ok = (name in _KNEADABLE and len(shp) >= 2
              and shp[-1] >= 128 and shp[-2] >= 128)
        if not ok:
            out.append(leaf)
            continue
        kdim = shp[-2]
        # scale [..., 1, N]: inherit only the weight's LAST-dim sharding
        # (size-1 dims cannot carry the weight's K-dim partitioning)
        wspec = leaf.sharding.spec
        last = wspec[len(shp) - 1] if len(wspec) >= len(shp) else None
        scale_sh = NamedSharding(leaf.sharding.mesh,
                                 P(*([None] * (len(shp) - 1) + [last])))
        scale_sds = jax.ShapeDtypeStruct(shp[:-2] + (1, shp[-1]),
                                         jnp.float32, sharding=scale_sh)
        if quant == "int8":
            q = jax.ShapeDtypeStruct(shp, jnp.int8, sharding=leaf.sharding)
            out.append(QuantizedTensor(q=q, scale=scale_sds, bits=8, axis=-1))
        elif quant == "int4":
            q = jax.ShapeDtypeStruct(shp[:-2] + (kdim // 2, shp[-1]),
                                     jnp.int8, sharding=leaf.sharding)
            out.append(PackedInt4(packed=q, scale=scale_sds, k=kdim))
        else:
            raise ValueError(quant)
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_sds(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
              b_axes=None) -> Dict:
    if b_axes is None:
        b_axes = sharding.batch_axes(mesh)
    bspec = b_axes if b_axes and shape.global_batch % int(
        np.prod([mesh.shape[a] for a in b_axes])) == 0 else None
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": _sds((b, s), i32, mesh, P(bspec, None)),
                 "labels": _sds((b, s), i32, mesh, P(bspec, None))}
    elif shape.kind == "prefill":
        batch = {"tokens": _sds((b, s), i32, mesh, P(bspec, None))}
    else:
        batch = {"token": _sds((b, 1), i32, mesh, P(bspec, None)),
                 "pos": _sds((b,), i32, mesh, P(bspec))}
    if cfg.family == "encdec" and shape.kind != "decode":
        batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), dt, mesh,
                               P(bspec, None, None))
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["image_embeds"] = _sds((b, cfg.num_image_tokens, cfg.d_model),
                                     dt, mesh, P(bspec, None, None))
    return batch


def cache_sds(model: LanguageModel, shape: InputShape, mesh: Mesh) -> PyTree:
    spec = model.cache_spec(shape.global_batch, shape.seq_len)
    shardings = sharding.cache_spec_sharding(spec, mesh, shape.global_batch)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        spec, shardings)


def default_train_config(cfg: ModelConfig, shape: InputShape,
                         mesh: Mesh, mode: str = "tp") -> TrainStepConfig:
    """Pick a microbatch that bounds per-device logits/activation memory."""
    axes = (sharding.dp_batch_axes(mesh, shape.global_batch)
            if mode == "dp" else sharding.batch_axes(mesh))
    n_batch_shards = int(np.prod([mesh.shape[a] for a in axes])) or 1
    mb = shape.global_batch
    # target: <= ~2^22 tokens*vocab bf16 per device per loss chunk; the loss
    # is seq-chunked already, so bound microbatch to 32 sequences for the
    # big-vocab archs and require divisibility by the batch shards.
    target = 32 if cfg.vocab_size >= 50_000 else 64
    if cfg.num_experts and cfg.sequence_parallel:
        # MoE: every microbatch re-gathers the FSDP-sharded expert weights
        # (dominant collective, §Perf it.3); SP keeps activations sharded,
        # so run the full batch in one shot.
        target = mb
    while mb > n_batch_shards and mb > target:
        mb //= 2
    mb = max(mb, n_batch_shards)
    state_dtype = "bfloat16" if cfg.param_count() > 5e10 else "float32"
    return TrainStepConfig(
        optimizer=adamw.AdamWConfig(state_dtype=state_dtype),
        microbatch=0 if mb >= shape.global_batch else mb,
        grad_dtype="bfloat16" if cfg.param_count() > 5e10 else "float32",
    )


def build_cell(arch: str, shape: InputShape, mesh: Mesh, *,
               smoke: bool = False, quant: Optional[str] = None,
               attn_impl: Optional[str] = None, kv_bits: int = 0):
    """Returns (step_fn, args_tuple, donate_argnums, meta)."""
    import dataclasses as dc
    cfg = get_config(arch, smoke=smoke)
    if attn_impl:
        cfg = dc.replace(cfg, attn_impl=attn_impl)
    if kv_bits and cfg.family in ("dense", "moe"):
        cfg = dc.replace(cfg, kv_cache_bits=kv_bits)
    model = LanguageModel(cfg)
    # "dp" profile applies to training only; serving uses the "tp" layout.
    # (A dedicated "serve" layout — output-dim-only sharding — was tried
    # and REFUTED for dense decode: the batch axis already occupies "data",
    # so combined-axis output sharding conflicts and the partitioner
    # reshards at +3x traffic; and it breaks MoE expert storage.  §Perf
    # iteration 7.  The decode weight-gather cost is instead attacked with
    # kneaded int8/int4 weights — the paper's own lever.)
    mode = cfg.parallelism if shape.kind == "train" else "tp"
    b_axes = (sharding.dp_batch_axes(mesh, shape.global_batch)
              if mode == "dp" else None)
    params = param_sds(model, mesh, mode)
    batch = batch_sds(cfg, shape, mesh, b_axes=b_axes)
    meta = {"arch": arch, "shape": shape.name, "kind": shape.kind,
            "quant": quant or "bf16", "parallelism": mode,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count()}

    if shape.kind == "train":
        ts = default_train_config(cfg, shape, mesh, mode=mode)
        step = make_train_step(
            model, ts,
            param_shardings=jax.tree.map(lambda l: l.sharding, params))
        opt_shapes = jax.eval_shape(
            functools.partial(adamw.init, cfg=ts.optimizer), params)
        opt_shardings = sharding.tree_shardings(opt_shapes, mesh, mode)
        opt = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            opt_shapes, opt_shardings)
        args = (params, opt, batch, None)
        meta["microbatch"] = ts.microbatch
        return step, args, (0, 1), meta

    # serving runs bf16 weights (training keeps f32 masters)
    params = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16,
                                       sharding=l.sharding)
        if jnp.issubdtype(l.dtype, jnp.floating) else l, params)
    qparams = quantize_param_sds(params, quant)
    if shape.kind == "prefill":
        def prefill_step(p, b):
            return model.prefill(p, b)
        return prefill_step, (qparams, batch), (), meta

    cache = cache_sds(model, shape, mesh)

    def decode_step(p, token, pos, c):
        return model.decode_step(p, token, pos, c)
    args = (qparams, batch["token"], batch["pos"], cache)
    return decode_step, args, (3,), meta
