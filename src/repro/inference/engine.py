"""Batched serving engine with the Tetris kneaded-weight path.

``ServingEngine`` owns: prefill -> padded KV cache -> batched greedy/sampled
decode, plus the ``submit()``/``drain()`` request front end (padding-bucket
micro-batches, per-request latency).  ``knead_params`` converts a trained
float checkpoint into a serving representation — either the quantized-matmul
form (QuantizedTensor int8 / PackedInt4: integer codes with a single
epilogue scale) or, with ``kneaded=True``, the full kneaded bit-plane form
of docs/DESIGN.md §7: every ``_KNEADABLE`` projection becomes a
:class:`KneadedWeight` with a compacted
:class:`~repro.core.schedule.KneadedSchedule`, stacked [L, K, N] scan-layer
weights kneaded per layer with a leading schedule axis
(:func:`repro.core.kneading.knead_stacked`), so attention and MLP
projections dispatch through ``sac_matmul`` — and with ``impl="pallas"``
through the schedule-walking SAC kernel's decode-GEMV fast path.

``shards=N`` (docs/DESIGN.md §8) additionally partitions every kneaded
projection's compacted work lists along the out-channel dim over an
N-device "model" mesh: stacked scan-layer weights become
:class:`~repro.core.schedule.ShardedStackedKneadedWeight` (per-layer
per-shard work lists, scan-sliceable), [K, N] leaves become
:class:`~repro.core.schedule.ShardedKneadedWeight`, and every sharded
matmul launches one Pallas call per device under ``jax.shard_map`` — the
same engine API, now tensor-parallel, bit-exact against one device.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.inference.frontend import (RequestFrontEnd, RequestHandle,
                                      validate_buckets)
from repro.inference.resilience import ServingFaultPolicy, verify_kneaded_tree
from repro.inference.scheduler import ContinuousScheduler
from repro.core.kneading import (KNEADABLE_NAMES, KneadedWeight,
                                 ShardedKneadedWeight,
                                 knead_padded, knead_stacked,
                                 shard_schedule, shard_stacked_schedule)
from repro.core.quantization import quantize
from repro.core.sac import SAC_IMPLS
from repro.kernels.kneaded_gemm.ref import pack_int4
from repro.models.layers import PackedInt4
from repro.models.lm import LanguageModel

PyTree = Any

_log = logging.getLogger(__name__)

# single shared definition (repro.core.kneading) — launch/specs.py reads the
# same tuple, so the two serving paths can't drift on what gets kneaded
_KNEADABLE = KNEADABLE_NAMES


def knead_params(params: PyTree, bits: int = 8, min_dim: int = 128,
                 *, kneaded: bool = False, ks: int = 256,
                 n_block: int = 128, shards: int = 0,
                 shard_partition: str = "contiguous") -> PyTree:
    """Convert every kneadable projection leaf to its serving form.

    Default (``kneaded=False``): quantize to intN codes — bits=8 ->
    QuantizedTensor; bits=4 -> PackedInt4 (nibble-packed along K).  Stacked
    [L, K, N] leaves are quantized per (layer, out-channel).

    ``kneaded=True``: the full bit-plane serving form — [K, N] leaves via
    :func:`~repro.core.kneading.knead_padded` (arbitrary dims zero-padded to
    tile alignment, exactly), leaves with any leading stack axes via
    :func:`~repro.core.kneading.knead_stacked` (per-slice schedules with the
    stack axes in front, sliced out by the model's layer scans): [L, K, N]
    scan-layer weights AND [L, E, K, N] MoE expert banks (docs/DESIGN.md
    §13 — each expert kneaded independently, served per-expert through the
    SAC decode-GEMV path).  ``min_dim`` gates tiny projections either way;
    kneadable leaves that stay un-kneaded are named in a one-line warning
    instead of silently serving their float/quant form.

    ``shards=N`` (with ``kneaded=True``) then partitions every kneaded
    leaf's work lists along N — stacked leaves per layer
    (:func:`~repro.core.kneading.shard_stacked_schedule`), [K, N] leaves via
    :func:`~repro.core.kneading.shard_schedule` — producing the mesh-ready
    sharded serving tree of docs/DESIGN.md §8 (a plain int here: placement
    happens at ``device_put`` time via
    ``runtime.sharding.kneaded_shardings``).  Expert banks are NOT
    N-sharded: they place whole experts on the "expert" mesh axis
    (``ServingConfig.expert_shards``).
    """
    if shards > 1 and not kneaded:
        raise ValueError("shards applies to the kneaded serving form only "
                         "(pass kneaded=True)")
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    unkneaded = []
    for path, leaf in flat:
        keys = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        name = keys[-1] if keys else ""
        ok = (name in _KNEADABLE and hasattr(leaf, "ndim") and leaf.ndim >= 2
              and leaf.shape[-1] >= min_dim and leaf.shape[-2] >= min_dim
              and leaf.shape[-2] % 2 == 0)
        if kneaded:
            ok = (name in _KNEADABLE and hasattr(leaf, "ndim")
                  and leaf.ndim >= 2
                  and leaf.shape[-1] >= min_dim
                  and leaf.shape[-2] >= min_dim)
            if not ok and name in _KNEADABLE and hasattr(leaf, "ndim"):
                unkneaded.append("/".join(keys) +
                                 f" {tuple(leaf.shape)}")
        if not ok:
            out.append(leaf)
            continue
        if kneaded:
            if leaf.ndim == 2:
                kw = knead_padded(leaf, bits=bits, ks=ks, n_block=n_block)
                if shards > 1:
                    kw = shard_schedule(kw, shards,
                                        partition=shard_partition)
            else:
                kw = knead_stacked(leaf, bits=bits, ks=ks, n_block=n_block)
                if shards > 1 and leaf.ndim == 3:
                    # expert banks (ndim >= 4) are never N-sharded: whole
                    # experts place on the "expert" mesh axis instead
                    kw = shard_stacked_schedule(kw, shards,
                                                partition=shard_partition)
            out.append(kw)
            continue
        qt = quantize(leaf, bits=bits, axis=-1, reduce_axes=(-2,))
        scale = qt.scale  # [..., 1, N] per (stack..., out-channel)
        if bits == 4:
            k = leaf.shape[-2]
            q2 = qt.q.reshape((-1,) + leaf.shape[-2:])
            packed = jnp.stack([pack_int4(q) for q in q2])
            packed = packed.reshape(leaf.shape[:-2] + (k // 2, leaf.shape[-1]))
            out.append(PackedInt4(packed=packed, scale=scale, k=k))
        else:
            out.append(dataclasses.replace(qt, scale=scale))
    if unkneaded:
        _log.warning("serving un-kneaded (below min_dim=%d): %s",
                     min_dim, ", ".join(unkneaded))
    return jax.tree_util.tree_unflatten(treedef, out)


def serving_bytes(params: PyTree) -> int:
    """HBM bytes of a serving param tree (bf16 floats, intN codes, or the
    packed kneaded format incl. schedule metadata; sharded leaves count
    across all shards)."""
    total = 0
    kinds = (KneadedWeight, ShardedKneadedWeight)
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, kinds)):
        if isinstance(leaf, kinds):
            total += leaf.packed_bytes()
        elif hasattr(leaf, "dtype") and hasattr(leaf, "size"):
            itemsize = jnp.dtype(leaf.dtype).itemsize
            if jnp.issubdtype(leaf.dtype, jnp.floating):
                itemsize = 2     # floats serve as bf16
            total += leaf.size * itemsize
    return total


@dataclasses.dataclass
class ServingConfig:
    max_len: int = 512
    temperature: float = 0.0      # 0 => greedy
    quant_bits: int = 0           # 0 => bf16, else 8 or 4
    # Serving execution path:
    #   "quant"  — the quantized-matmul form above (quant_bits selects width)
    #   "float"  — original float params, plain bf16 matmuls
    #   "int" | "planes" | "pallas" — knead every projection to the bit-plane
    #            form and run SAC through that path ("pallas" = the
    #            schedule-compacted kernel with the decode-GEMV fast path;
    #            "planes" = its bit-exact oracle; "int" = one integer-code
    #            matmul, the fast CPU reference).  Kneading width is
    #            quant_bits (default 8 when 0).
    impl: str = "quant"
    knead_ks: int = 256           # kneading stride == kernel K tile
    knead_n_block: int = 128      # kernel N tile / schedule granularity
    knead_min_dim: int = 128      # skip projections smaller than this
    # Shard every kneaded projection's compacted schedule along its
    # out-channel dim over this many "model"-mesh devices (0/1 = single
    # device).  Requires impl="pallas" — sharded work lists are a kernel-
    # path artifact (docs/DESIGN.md §8).
    shards: int = 0
    # Tile→shard partitioning of sharded schedules (docs/DESIGN.md §11):
    #   "contiguous" — each shard takes a contiguous N-tile slab
    #   "balanced"   — LPT bin-packing on static per-tile occupancy, with
    #                  a recorded permutation gathered back after the
    #                  per-device kernels (bit-exact either way)
    shard_partition: str = "contiguous"
    # Expert parallelism for kneaded MoE banks (docs/DESIGN.md §13): place
    # whole experts of every [L, E, K, N] kneaded bank on a dedicated
    # "expert" mesh axis (0/1 = all experts local).  Orthogonal to
    # ``shards`` — the mesh becomes ("expert", "model"), expert banks
    # shard on "expert", the dense projections' N-shards stay on "model".
    # Requires a kneaded impl ("int"/"planes"/"pallas") and
    # num_experts % expert_shards == 0; bit-exact vs all-experts-local
    # through the combine psum.
    expert_shards: int = 0
    mesh_axis: str = "model"
    # submit()/drain() batching: micro-batch padding buckets (ascending)
    # and the sliding per-request latency log window.
    buckets: Tuple[int, ...] = (1, 2, 4, 8)
    stats_window: int = 4096
    # Request scheduler (docs/DESIGN.md §9):
    #   "batch"      — submit() queues, drain() serves padding-bucket
    #                  micro-batches to completion (wave-synchronous).
    #   "continuous" — step-level slot scheduler: prompts admit into free
    #                  slots each step, finished requests retire (and free
    #                  their KV blocks) immediately; handles stream tokens
    #                  as they decode.  drain() remains a thin wrapper.
    scheduler: str = "batch"
    max_inflight: int = 8         # continuous: in-flight slot capacity
    # continuous KV pool: block granularity in tokens (0 = dense rows at
    # max_len) and total pool budget in tokens (0 = slots * max_len)
    kv_block: int = 32
    kv_pool_tokens: int = 0
    # continuous: cap on admitted prompt tokens per scheduler step (0 =
    # uncapped) — bounds how much prefill work interleaves one decode step
    prefill_chunk: int = 0
    # Runtime activation-side skip (two-sided skip, docs/DESIGN.md §12):
    # intersect per-K-tile presence bits from the decode activation row
    # into every kneaded projection's schedule walk.  Decode-GEMV steps
    # only (prefill falls back to the static weight-only skip); bit-exact
    # on/off.  Effective on the kneaded impls; "quant"/"float" ignore it.
    # Surfaces executed_tile_dots / act_skip_frac in latency_stats().
    activation_skip: bool = False
    # Fault handling (docs/DESIGN.md §10): bounded per-request retries,
    # NaN-logit quarantine, decode-step watchdog, impl-demotion ladder,
    # and knead-time checksum verification.  None (default) keeps the
    # pre-resilience behavior exactly — no guards, exceptions propagate.
    fault_policy: Optional[ServingFaultPolicy] = None


class ServingEngine(RequestFrontEnd):
    def __init__(self, cfg: ModelConfig, params: PyTree,
                 scfg: ServingConfig = ServingConfig()):
        if scfg.impl not in ("quant",) + SAC_IMPLS:
            raise ValueError(f"impl must be 'quant' or one of {SAC_IMPLS}, "
                             f"got {scfg.impl!r}")
        if scfg.shards > 1 and scfg.impl != "pallas":
            raise ValueError("sharded serving runs the Pallas kernel; "
                             f"impl={scfg.impl!r} is single-device only")
        if scfg.expert_shards > 1:
            if scfg.impl not in SAC_IMPLS:
                raise ValueError(
                    "expert_shards places kneaded expert banks on the "
                    f"'expert' mesh axis; impl={scfg.impl!r} does not "
                    f"knead (use one of {SAC_IMPLS})")
            if not cfg.num_experts:
                raise ValueError("expert_shards requires an MoE config "
                                 f"(num_experts=0 in {cfg.name!r})")
            if cfg.num_experts % scfg.expert_shards:
                raise ValueError(
                    f"num_experts={cfg.num_experts} not divisible by "
                    f"expert_shards={scfg.expert_shards}")
        if scfg.scheduler not in ("batch", "continuous"):
            raise ValueError(f"scheduler must be 'batch' or 'continuous', "
                             f"got {scfg.scheduler!r}")
        if scfg.scheduler == "continuous":
            if cfg.family in ("vlm", "encdec"):
                raise ValueError(
                    f"continuous scheduler serves token-prompt families "
                    f"only; {cfg.family!r} prefill needs side inputs "
                    f"(frames/image embeddings) — use scheduler='batch'")
            if scfg.max_inflight < 1:
                raise ValueError("max_inflight must be >= 1")
        validate_buckets(scfg.buckets)
        self.scfg = scfg
        self.mesh = None
        # fault policy keeps the float checkpoint around: the integrity
        # repair path re-kneads corrupt leaves from it (a tree of
        # references, not a copy — the caller holds these arrays anyway)
        self._float_params = params if scfg.fault_policy is not None \
            else None
        integrity_report = []
        if scfg.impl in ("quant", "float"):
            self.cfg = cfg
            self.params = (knead_params(params, bits=scfg.quant_bits,
                                        min_dim=scfg.knead_min_dim)
                           if scfg.impl == "quant" and scfg.quant_bits
                           else params)
        else:
            # kneaded serving: the model dispatches every KneadedWeight
            # matmul through the configured SAC path (and, when asked, the
            # runtime activation-side skip — decode-GEMV only, bit-exact)
            self.cfg = dataclasses.replace(
                cfg, impl=scfg.impl,
                activation_skip=scfg.activation_skip)
            self.params = knead_params(
                params, bits=scfg.quant_bits or 8,
                min_dim=scfg.knead_min_dim, kneaded=True,
                ks=scfg.knead_ks, n_block=scfg.knead_n_block,
                shards=scfg.shards,
                shard_partition=scfg.shard_partition)
            if scfg.fault_policy is not None and \
                    scfg.fault_policy.verify_weights:
                # before device placement: a repaired leaf re-kneads on
                # host, so sharded trees verify pre-device_put
                self.params, integrity_report = verify_kneaded_tree(
                    self.params, self._float_params, shards=scfg.shards)
            if scfg.shards > 1 or scfg.expert_shards > 1:
                from repro.launch.mesh import make_serving_mesh
                from repro.runtime.sharding import kneaded_shardings
                self.mesh = make_serving_mesh(
                    max(scfg.shards, 1),
                    expert_shards=max(scfg.expert_shards, 1))
                self.params = jax.device_put(
                    self.params, kneaded_shardings(self.params, self.mesh,
                                                   axis=scfg.mesh_axis))
        cfg = self.cfg
        self.model = LanguageModel(cfg)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(3,))
        self._init_front_end(scfg.stats_window)
        for row in integrity_report:
            self._fault_event("integrity_repairs", **row)
        self._scheduler = (ContinuousScheduler(self)
                           if scfg.scheduler == "continuous" else None)

    # ------------------------------------------- resilience (§10; policy)

    def _demote_impl(self, reason: str) -> bool:
        """Graceful degradation: move one rung down the fault policy's
        ``fallback_impls`` ladder and rebuild the jitted model functions.

        Possible because every SAC impl dispatches per call on the same
        :class:`~repro.core.kneading.KneadedWeight` params
        (``matmul_any -> sac_matmul``) — no re-kneading, no new device
        placement, just a re-jit.  ``pallas -> planes`` preserves the
        bit-exactness guarantee (planes is the kernel's bitwise oracle);
        ``planes -> float`` trades exactness for availability and is why
        every demotion logs a ``degradations`` event.  Returns False —
        never raises — when no rung remains, the engine is not on a
        kneaded impl, or the engine is sharded (sharded work lists are a
        Pallas-kernel artifact; there is no weaker impl that can read
        them, docs/DESIGN.md §8).
        """
        pol = self.scfg.fault_policy
        cur = self.scfg.impl
        if pol is None or not pol.fallback_impls:
            return False
        if cur in ("quant", "float") or self.scfg.shards > 1:
            return False
        ladder = list(pol.fallback_impls)
        if cur in ladder:
            nxt = ladder[ladder.index(cur) + 1] \
                if ladder.index(cur) + 1 < len(ladder) else None
        else:
            nxt = ladder[0]       # e.g. pallas -> head of (planes, float)
        if nxt is None or nxt == cur or nxt not in SAC_IMPLS:
            return False
        self.scfg = dataclasses.replace(self.scfg, impl=nxt)
        self.cfg = dataclasses.replace(self.cfg, impl=nxt)
        self.model = LanguageModel(self.cfg)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(3,))
        self._fault_event("degradations", impl_from=cur, impl_to=nxt,
                          reason=reason)
        return True

    def verify_weights(self, repair: bool = True):
        """Sweep the serving params for corrupted kneaded leaves (bit
        flips in planes/signs/occupancy or the compacted schedule arrays,
        checked against knead-time CRCs).  With ``repair``, corrupt
        leaves are re-kneaded from the retained float checkpoint —
        deterministic, hence bit-identical to the never-corrupted leaf.
        Returns the corruption report (empty = intact); logs one
        ``integrity_repairs`` event per repaired leaf.
        """
        self.params, report = verify_kneaded_tree(
            self.params, self._float_params, shards=self.scfg.shards,
            repair=repair)
        for row in report:
            self._fault_event("integrity_repairs", **row)
        return report

    def expert_work_table(self) -> Dict[str, Any]:
        """Static per-(layer, expert) kneaded work tables, one [L, E] host
        numpy array per kneaded expert bank ({path: table}).

        The ``layer_shard_work`` analogue for expert parallelism
        (docs/DESIGN.md §13): entry [l, e] is how many (plane, K-tile,
        N-tile) work items expert e of layer l owns in the compacted
        schedule — the static side of the routing-load accounting
        (``latency_stats()``'s ``routed_tokens``/``capacity_dropped``
        counters are the dynamic side), and the input the ROADMAP
        work-stealing item needs.  Empty for non-MoE / un-kneaded engines.
        """
        tables: Dict[str, Any] = {}
        flat, _ = jax.tree_util.tree_flatten_with_path(
            self.params, is_leaf=lambda x: isinstance(x, KneadedWeight))
        for path, leaf in flat:
            if isinstance(leaf, KneadedWeight) and leaf.planes.ndim >= 5:
                name = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                                for k in path)
                tables[name] = leaf.work_table()
        return tables

    def _mesh_ctx(self):
        """Serving-mesh context the sharded kneaded matmuls dispatch under
        (a no-op for unsharded engines; installed around every model call so
        jit traces capture the mesh — docs/DESIGN.md §8)."""
        import contextlib

        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.runtime.sharding import serving_mesh
        return serving_mesh(self.mesh, self.scfg.mesh_axis)

    def _pad_cache(self, cache: PyTree, cur: int) -> PyTree:
        """Pad the prefill cache's sequence axes out to ``max_len``.

        Structure-aware, keyed on the cache dict the model families build
        (models/lm.py): self-attention KV stores ("k"/"v", seq axis at -3)
        zero-pad; their int8-quantization scales ("k_scale"/"v_scale", seq
        at -2) pad with 1.0.  Everything else — cross-attention KV (fixed
        encoder/image extent) and SSM/conv states ("conv"/"ssm"/"mlstm"/
        "slstm", no seq axis at all) — is returned untouched.  Keying on
        names rather than sniffing shapes matters: a hybrid SSM state
        [L, B, H, p, n] whose head count H happens to equal the prompt
        length used to match the old "-3 axis == prefill len" heuristic and
        got its *heads* padded to max_len, breaking every zamba2 decode
        (the ROADMAP's hybrid-decode bug; regression-tested in
        tests/test_lm_kneaded.py).
        """
        pad_to = self.scfg.max_len

        def pad_axis(x, axis, value=0.0):
            if x.shape[axis] != cur or pad_to == cur:
                return x
            pads = [(0, 0)] * x.ndim
            pads[axis] = (0, pad_to - cur)
            return jnp.pad(x, pads, constant_values=value)

        out = dict(cache)
        for key in ("k", "v"):
            if key in out:
                out[key] = pad_axis(out[key], -3)
        for key in ("k_scale", "v_scale"):
            if key in out:
                out[key] = pad_axis(out[key], -2, value=1.0)
        return out

    def generate(self, batch: Dict[str, jax.Array], num_tokens: int,
                 key: Optional[jax.Array] = None) -> jax.Array:
        """Prefill on batch["tokens"] then decode ``num_tokens`` greedily
        (or sampled at temperature>0).  Returns [B, num_tokens] int32."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        assert s + num_tokens <= self.scfg.max_len
        # virtual-launch clock: one prefill + num_tokens decode launches
        self.ticks += 1 + num_tokens
        with self._mesh_ctx():
            logits, cache = self._prefill(self.params, batch)
            cache = self._pad_cache(cache, s)
            outs = []
            key = key if key is not None else jax.random.PRNGKey(0)
            tok = self._select(logits, key)
            for i in range(num_tokens):
                outs.append(tok)
                pos = jnp.full((b,), s + i, jnp.int32)
                logits, cache = self._decode(self.params, tok[:, None], pos,
                                             cache)
                key, sub = jax.random.split(key)
                tok = self._select(logits, sub)
            return jnp.stack(outs, axis=1)

    def _select(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.scfg.temperature,
            axis=-1).astype(jnp.int32)

    # ------------------------------------------- batched request front end

    def submit(self, tokens: jax.Array, num_tokens: int = 16, *,
               priority: int = 0,
               deadline: Optional[float] = None) -> RequestHandle:
        """Queue one single-prompt generation request.

        ``tokens`` is a 1-D int32 prompt.  Returns a
        :class:`~repro.inference.frontend.RequestHandle` — it compares/
        hashes as the integer request id (so the classic
        ``results = drain(); results[rid]`` flow is unchanged) and adds
        ``result()`` (block for this request), ``stream()`` (per-token
        iterator), and ``cancel()``.  ``priority`` orders admission under
        the continuous scheduler (higher first; FIFO within a priority);
        ``deadline`` (seconds from now) expires the request if it is
        still queued when the scheduler next looks at it.
        """
        if getattr(tokens, "ndim", None) != 1:
            raise ValueError("submit takes one prompt [S], got shape "
                             f"{tuple(getattr(tokens, 'shape', ()))}")
        if tokens.shape[0] < 1:
            raise ValueError("prompt must contain at least one token")
        if num_tokens < 1:
            raise ValueError(f"num_tokens must be >= 1, got {num_tokens}")
        total = int(tokens.shape[0]) + num_tokens
        if total > self.scfg.max_len:
            raise ValueError(f"prompt {tokens.shape[0]} + {num_tokens} "
                             f"tokens exceeds max_len={self.scfg.max_len}")
        if self._scheduler is not None and not self._scheduler.pool.fits(
                total):
            raise ValueError(
                f"request needs {total} KV tokens "
                f"({self._scheduler.pool.blocks_needed(total)} blocks) but "
                f"the pool holds {self._scheduler.pool.total_blocks} blocks "
                f"of {self._scheduler.pool.block} "
                f"(kv_pool_tokens={self.scfg.kv_pool_tokens})")
        return self._new_request(tokens, num_tokens, priority=priority,
                                 deadline=deadline)

    def drain(self) -> Dict[int, jax.Array]:
        """Serve every pending request; returns {request_id: tokens [n_i]}.

        Under ``scheduler="continuous"`` this is a thin compatibility
        wrapper: it runs the step loop until the wave that was pending at
        call time retires (admission/retirement still happen per step
        inside).  The batch-synchronous path below groups pending
        requests by prompt length (one prefill shape per group —
        positions stay exact with no prompt padding), then splits into
        chunks of at most ``max(buckets)``; each chunk stacks on the
        batch axis and zero-pads up to the smallest bucket that fits, so
        the jitted prefill/decode compile once per (prompt-len, bucket)
        rather than once per request count — the padded rows ride the
        kernel grid's M dimension.  The chunk decodes jointly for the
        chunk-max token budget (requests with smaller budgets finish
        early and their rows ride along as padding) and each request
        keeps its first ``num_tokens``.
        """
        if self._scheduler is not None:
            return self._scheduler.drain()
        from repro.inference import frontend as fe
        buckets = self.scfg.buckets
        cap = buckets[-1]
        results: Dict[int, jax.Array] = {}
        by_len: Dict[int, List] = collections.defaultdict(list)
        for req in self._pending:
            by_len[req.prompt_len].append(req)
        self._pending = []
        for plen in sorted(by_len):
            queue = by_len[plen]
            while queue:
                chunk, queue = queue[:cap], queue[cap:]
                b = len(chunk)
                bucket = next(bk for bk in buckets if bk >= b)
                start = time.perf_counter()
                start_tick = self.ticks
                toks = jnp.stack([r.payload for r in chunk])
                if bucket > b:
                    toks = jnp.pad(toks, ((0, bucket - b), (0, 0)))
                budget = max(r.num_tokens for r in chunk)
                out = jax.block_until_ready(
                    self.generate({"tokens": toks}, budget))
                done = time.perf_counter()
                for i, req in enumerate(chunk):
                    req.state = fe.DONE
                    req.result = out[i, :req.num_tokens]
                    req.admit_t, req.finish_t = start, done
                    req.admit_tick = start_tick
                    req.finish_tick = self.ticks
                    results[req.id] = req.result
                    self._log_request(
                        id=req.id,
                        latency_ms=(done - req.submit_t) * 1e3,
                        queue_wait_ms=(start - req.submit_t) * 1e3,
                        decode_ms=(done - start) * 1e3,
                        latency_ticks=self.ticks - req.submit_tick,
                        queue_wait_ticks=start_tick - req.submit_tick,
                        bucket=bucket,
                        batch_fill=b / bucket,
                        prompt_len=plen,
                        decode_tokens=budget,
                    )
        return results

    # ---- RequestHandle backends (continuous mode steps the scheduler
    # just far enough; batch mode falls back to the mixin's drain-all)

    def _result(self, req):
        if self._scheduler is not None:
            self._scheduler.run_until(req)
            return self._finished_result(req)
        return super()._result(req)

    def _stream(self, req):
        if self._scheduler is not None:
            return self._scheduler.stream(req)
        return super()._stream(req)

    def _cancel(self, req) -> bool:
        if self._scheduler is not None:
            return self._scheduler.cancel(req)
        return super()._cancel(req)

    def scheduler_step(self) -> bool:
        """Advance the continuous scheduler by one step (admit -> decode
        -> retire).  Returns True while work remains.  Batch mode: error."""
        if self._scheduler is None:
            raise ValueError("scheduler_step() requires "
                             "ServingConfig(scheduler='continuous')")
        return self._scheduler.step()
