"""Batched serving engine with the Tetris kneaded-weight path.

``ServingEngine`` owns: prefill -> padded KV cache -> batched greedy/sampled
decode.  ``knead_params`` converts a trained float checkpoint into a serving
representation — either the quantized-matmul form (QuantizedTensor int8 /
PackedInt4: integer codes with a single epilogue scale) or, with
``kneaded=True``, the full kneaded bit-plane form of docs/DESIGN.md §7:
every ``_KNEADABLE`` projection becomes a :class:`KneadedWeight` with a
compacted :class:`~repro.core.schedule.KneadedSchedule`, stacked [L, K, N]
scan-layer weights kneaded per layer with a leading schedule axis
(:func:`repro.core.kneading.knead_stacked`), so attention and MLP
projections dispatch through ``sac_matmul`` — and with ``impl="pallas"``
through the schedule-walking SAC kernel's decode-GEMV fast path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.kneading import KneadedWeight, knead_padded, knead_stacked
from repro.core.quantization import quantize
from repro.core.sac import SAC_IMPLS
from repro.kernels.kneaded_gemm.ref import pack_int4
from repro.models.layers import PackedInt4
from repro.models.lm import LanguageModel

PyTree = Any

_KNEADABLE = ("wq", "wk", "wv", "wo", "wi", "wi_gate", "wi_up", "up",
              "down", "w_in", "w_out", "in_proj", "out_proj", "unembed")


def knead_params(params: PyTree, bits: int = 8, min_dim: int = 128,
                 *, kneaded: bool = False, ks: int = 256,
                 n_block: int = 128) -> PyTree:
    """Convert every kneadable projection leaf to its serving form.

    Default (``kneaded=False``): quantize to intN codes — bits=8 ->
    QuantizedTensor; bits=4 -> PackedInt4 (nibble-packed along K).  Stacked
    [L, K, N] leaves are quantized per (layer, out-channel).

    ``kneaded=True``: the full bit-plane serving form — [K, N] leaves via
    :func:`~repro.core.kneading.knead_padded` (arbitrary dims zero-padded to
    tile alignment, exactly), stacked [L, K, N] scan-layer leaves via
    :func:`~repro.core.kneading.knead_stacked` (per-layer schedules with a
    leading layer axis, sliced out by the model's layer scans).  Leaves with
    more than one stack dim (MoE expert banks — executed inside shard_map)
    stay float; ``min_dim`` gates tiny projections either way.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        keys = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        name = keys[-1] if keys else ""
        ok = (name in _KNEADABLE and hasattr(leaf, "ndim") and leaf.ndim >= 2
              and leaf.shape[-1] >= min_dim and leaf.shape[-2] >= min_dim
              and leaf.shape[-2] % 2 == 0)
        if kneaded:
            ok = (name in _KNEADABLE and hasattr(leaf, "ndim")
                  and leaf.ndim in (2, 3)
                  and leaf.shape[-1] >= min_dim
                  and leaf.shape[-2] >= min_dim)
        if not ok:
            out.append(leaf)
            continue
        if kneaded:
            if leaf.ndim == 2:
                out.append(knead_padded(leaf, bits=bits, ks=ks,
                                        n_block=n_block))
            else:
                out.append(knead_stacked(leaf, bits=bits, ks=ks,
                                         n_block=n_block))
            continue
        qt = quantize(leaf, bits=bits, axis=-1, reduce_axes=(-2,))
        scale = qt.scale  # [..., 1, N] per (stack..., out-channel)
        if bits == 4:
            k = leaf.shape[-2]
            q2 = qt.q.reshape((-1,) + leaf.shape[-2:])
            packed = jnp.stack([pack_int4(q) for q in q2])
            packed = packed.reshape(leaf.shape[:-2] + (k // 2, leaf.shape[-1]))
            out.append(PackedInt4(packed=packed, scale=scale, k=k))
        else:
            out.append(dataclasses.replace(qt, scale=scale))
    return jax.tree_util.tree_unflatten(treedef, out)


def serving_bytes(params: PyTree) -> int:
    """HBM bytes of a serving param tree (bf16 floats, intN codes, or the
    packed kneaded format incl. schedule metadata)."""
    total = 0
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, KneadedWeight)):
        if isinstance(leaf, KneadedWeight):
            total += leaf.packed_bytes()
        elif hasattr(leaf, "dtype") and hasattr(leaf, "size"):
            itemsize = jnp.dtype(leaf.dtype).itemsize
            if jnp.issubdtype(leaf.dtype, jnp.floating):
                itemsize = 2     # floats serve as bf16
            total += leaf.size * itemsize
    return total


@dataclasses.dataclass
class ServingConfig:
    max_len: int = 512
    temperature: float = 0.0      # 0 => greedy
    quant_bits: int = 0           # 0 => bf16, else 8 or 4
    # Serving execution path:
    #   "quant"  — the quantized-matmul form above (quant_bits selects width)
    #   "float"  — original float params, plain bf16 matmuls
    #   "int" | "planes" | "pallas" — knead every projection to the bit-plane
    #            form and run SAC through that path ("pallas" = the
    #            schedule-compacted kernel with the decode-GEMV fast path;
    #            "planes" = its bit-exact oracle; "int" = one integer-code
    #            matmul, the fast CPU reference).  Kneading width is
    #            quant_bits (default 8 when 0).
    impl: str = "quant"
    knead_ks: int = 256           # kneading stride == kernel K tile
    knead_n_block: int = 128      # kernel N tile / schedule granularity
    knead_min_dim: int = 128      # skip projections smaller than this


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: PyTree,
                 scfg: ServingConfig = ServingConfig()):
        if scfg.impl not in ("quant",) + SAC_IMPLS:
            raise ValueError(f"impl must be 'quant' or one of {SAC_IMPLS}, "
                             f"got {scfg.impl!r}")
        self.scfg = scfg
        if scfg.impl in ("quant", "float"):
            self.cfg = cfg
            self.params = (knead_params(params, bits=scfg.quant_bits,
                                        min_dim=scfg.knead_min_dim)
                           if scfg.impl == "quant" and scfg.quant_bits
                           else params)
        else:
            # kneaded serving: the model dispatches every KneadedWeight
            # matmul through the configured SAC path
            self.cfg = dataclasses.replace(cfg, sac_impl=scfg.impl)
            self.params = knead_params(
                params, bits=scfg.quant_bits or 8,
                min_dim=scfg.knead_min_dim, kneaded=True,
                ks=scfg.knead_ks, n_block=scfg.knead_n_block)
        cfg = self.cfg
        self.model = LanguageModel(cfg)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(3,))

    def _pad_cache(self, cache: PyTree, cur: int) -> PyTree:
        pad_to = self.scfg.max_len

        def pad(x):
            # attention caches: seq axis at -3; scale arrays: seq at -2
            if x.ndim >= 4 and x.shape[-3] == cur:
                pads = [(0, 0)] * x.ndim
                pads[-3] = (0, pad_to - cur)
                return jnp.pad(x, pads)
            if (x.ndim >= 3 and x.shape[-2] == cur
                    and x.dtype == jnp.float32):
                pads = [(0, 0)] * x.ndim
                pads[-2] = (0, pad_to - cur)
                return jnp.pad(x, pads, constant_values=1.0)
            return x
        return jax.tree.map(pad, cache)

    def generate(self, batch: Dict[str, jax.Array], num_tokens: int,
                 key: Optional[jax.Array] = None) -> jax.Array:
        """Prefill on batch["tokens"] then decode ``num_tokens`` greedily
        (or sampled at temperature>0).  Returns [B, num_tokens] int32."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        assert s + num_tokens <= self.scfg.max_len
        logits, cache = self._prefill(self.params, batch)
        cache = self._pad_cache(cache, s)
        outs = []
        key = key if key is not None else jax.random.PRNGKey(0)
        tok = self._select(logits, key)
        for i in range(num_tokens):
            outs.append(tok)
            pos = jnp.full((b,), s + i, jnp.int32)
            logits, cache = self._decode(self.params, tok[:, None], pos,
                                         cache)
            key, sub = jax.random.split(key)
            tok = self._select(logits, sub)
        return jnp.stack(outs, axis=1)

    def _select(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.scfg.temperature,
            axis=-1).astype(jnp.int32)
