"""CNN serving engine — the paper's own workload, served fully kneaded.

``CNNServingEngine`` is the CNN sibling of the LM ``ServingEngine``: it takes
a trained float checkpoint of an AlexNet/VGG-16/NiN-style model, converts
every conv/fc layer to the kneaded bit-plane format (conv layers via their
im2col [C*kh*kw, out_ch] matrices, zero-padded to tile alignment), and runs
the whole forward pass through the selected SAC execution path:

  impl="float"   — original float weights, plain f32 matmuls (the baseline)
  impl="int"     — integer-code matmul, scale in the epilogue (production CPU)
  impl="planes"  — paper-faithful per-plane SAC (the kernel's semantic oracle)
  impl="pallas"  — the schedule-compacted Pallas kernel (interpret on CPU,
                   compiled on TPU): each conv layer is ONE pallas_call whose
                   grid streams all activation rows and executes only the
                   work items of the layer's KneadedSchedule — built once
                   here at engine init (inside knead) and stored on each
                   KneadedWeight

"planes" and "pallas" are bit-exact against each other; all kneaded paths
match the float model within the quantization error bound.

Scaling (docs/DESIGN.md §5):

* ``shards=N`` partitions every layer's KneadedSchedule along its
  out-channel dimension over an N-device "model" mesh — the Pallas kernel
  then launches once per device under ``jax.shard_map``, each device
  executing only *its shard's* occupancy nonzeros (sharded == single-device
  bit-exact; ``layer_report`` adds per-shard work + imbalance columns).
* ``submit()``/``drain()`` is the batched request front end: single-image
  requests queue and drain in padding-bucket micro-batches — the stacked
  batch pads up to a fixed bucket size so the jitted forward compiles once
  per bucket while the kernel grid's M dimension absorbs the extra rows —
  with per-request latency recorded (``latency_stats``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.kneading import (KneadedWeight, ShardedKneadedWeight,
                                 kneaded_codes, kneading_ratio)
from repro.core.quantization import quantize
from repro.core.sac import SAC_IMPLS
from repro.inference.frontend import (RequestFrontEnd, RequestHandle,
                                      validate_buckets)
from repro.inference.resilience import ServingFaultPolicy
from repro.models import cnn

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CNNServingConfig:
    impl: str = "int"          # "float" | "int" | "planes" | "pallas"
    bits: int = 8              # kneaded fixed-point width
    ks: int = 256              # kneading stride == kernel K tile
    n_block: int = 128         # kernel N tile (occupancy/schedule granularity)
    jit: bool = True
    # Retain the float checkpoint after kneading so layer_report() can
    # derive cycle statistics cheaply.  Set False for long-lived serving
    # processes that only need the forward pass — the kneaded params alone
    # then realize the advertised ~bits/16 memory footprint in-process, and
    # layer_report() falls back to reconstructing codes from the packed
    # planes (exact, just slower).
    keep_float_params: bool = True
    # Shard every layer's kneaded weight + schedule along N over this many
    # mesh devices (0/1 = single device).  Requires impl="pallas" — the
    # sharded work lists are a kernel-path artifact.
    shards: int = 0
    # tile→shard partitioning of the sharded schedules: "contiguous" slabs
    # or occupancy-"balanced" LPT packing (docs/DESIGN.md §11)
    shard_partition: str = "contiguous"
    mesh_axis: str = "model"
    # Micro-batch padding buckets for submit()/drain(), ascending.  A drain
    # chunk pads to the smallest bucket that fits so the jitted forward
    # compiles once per bucket instead of once per request count.
    buckets: Tuple[int, ...] = (1, 2, 4, 8)
    # Per-request log entries retained for latency_stats() — a sliding
    # window, so a long-lived serving process doesn't grow without bound.
    stats_window: int = 4096
    # Fault handling (docs/DESIGN.md §10).  The CNN path is a single
    # forward per micro-batch — no retries/slots to recover — so only the
    # policy's NaN/Inf logit guard applies here: a non-finite logits row
    # FAILs just that request instead of returning garbage for the batch.
    fault_policy: Optional[ServingFaultPolicy] = None


class CNNServingEngine(RequestFrontEnd):
    """Classify images through a fully-kneaded CNN forward pass."""

    def __init__(self, cfg: cnn.CNNConfig, params: PyTree,
                 scfg: CNNServingConfig = CNNServingConfig()):
        if scfg.impl not in SAC_IMPLS:
            raise ValueError(f"impl must be one of {SAC_IMPLS}, "
                             f"got {scfg.impl!r}")
        if scfg.shards > 1 and scfg.impl != "pallas":
            raise ValueError("sharded serving runs the Pallas kernel; "
                             f"impl={scfg.impl!r} is single-device only")
        validate_buckets(scfg.buckets)
        self.cfg, self.scfg = cfg, scfg
        self.mesh = None
        if scfg.impl == "float":
            self.params = params
            self.float_params = params
        else:
            self.params = cnn.knead_params(params, bits=scfg.bits,
                                           ks=scfg.ks, n_block=scfg.n_block)
            self.float_params = params if scfg.keep_float_params else None
            if scfg.shards > 1:
                from repro.launch.mesh import make_model_mesh
                from repro.runtime.sharding import kneaded_shardings
                self.mesh = make_model_mesh(scfg.shards)
                self.params = cnn.shard_kneaded_params(
                    self.params, self.mesh, axis=scfg.mesh_axis,
                    partition=scfg.shard_partition)
                self.params = jax.device_put(
                    self.params, kneaded_shardings(self.params, self.mesh,
                                                   axis=scfg.mesh_axis))

        def fwd(p, x):
            return cnn.apply(p, x, cfg, impl=scfg.impl, mesh=self.mesh,
                             shard_axis=scfg.mesh_axis)

        self._fwd = jax.jit(fwd) if scfg.jit else fwd
        self._init_front_end(scfg.stats_window)

    def logits(self, x: jax.Array) -> jax.Array:
        """x [B, H, W, C] -> logits [B, num_classes]."""
        return self._fwd(self.params, x)

    def classify(self, x: jax.Array) -> jax.Array:
        """x [B, H, W, C] -> predicted class ids [B] int32."""
        return jnp.argmax(self.logits(x), axis=-1).astype(jnp.int32)

    # ------------------------------------------------- batched request front end

    def submit(self, x: jax.Array) -> "RequestHandle":
        """Queue one single-image request [H, W, C].

        Returns a :class:`~repro.inference.frontend.RequestHandle` (an
        int-compatible request id with ``result()``/``stream()``/
        ``cancel()``).  Requests accumulate until :meth:`drain` runs them
        in padding-bucket micro-batches; per-request latency is measured
        from this call to the completion of the micro-batch that served
        it.  The image shape is validated here, against the model config,
        so a bad request fails at submit with a clear error rather than
        as a shape mismatch deep inside the jitted forward.
        """
        if x.ndim != 3:
            raise ValueError(f"submit takes one image [H, W, C], "
                             f"got shape {tuple(x.shape)}")
        want = (self.cfg.image_size, self.cfg.image_size,
                self.cfg.in_channels)
        if tuple(x.shape) != want:
            raise ValueError(f"image shape {tuple(x.shape)} does not match "
                             f"the model's input {want} "
                             f"(image_size={self.cfg.image_size}, "
                             f"in_channels={self.cfg.in_channels})")
        return self._new_request(x)

    def drain(self) -> Dict[int, jax.Array]:
        """Serve every pending request; returns {request_id: logits}.

        Pending requests split into chunks of at most ``max(buckets)``
        images; each chunk stacks on the batch axis and zero-pads up to the
        smallest bucket that fits (the padded rows ride the kernel grid's M
        dimension and are sliced off), so the jitted forward sees one shape
        per bucket — no per-request-count retraces.
        """
        from repro.inference import frontend as fe
        buckets = self.scfg.buckets
        cap = buckets[-1]
        results: Dict[int, jax.Array] = {}
        while self._pending:
            chunk, self._pending = self._pending[:cap], self._pending[cap:]
            b = len(chunk)
            bucket = next(bk for bk in buckets if bk >= b)
            start = time.perf_counter()
            start_tick = self.ticks
            xb = jnp.stack([r.payload for r in chunk])
            if bucket > b:
                xb = jnp.pad(xb, ((0, bucket - b),) + ((0, 0),) * 3)
            self.ticks += 1                     # one jitted forward launch
            out = jax.block_until_ready(self.logits(xb))[:b]
            done = time.perf_counter()
            pol = self.scfg.fault_policy
            bad_rows = set()
            if pol is not None and pol.nan_guard:
                import numpy as np
                finite = np.isfinite(np.asarray(out).astype(np.float32))
                bad_rows = {i for i in range(b) if not finite[i].all()}
            for i, req in enumerate(chunk):
                if i in bad_rows:
                    req.state = fe.FAILED
                    req.error = "non-finite logits"
                    req.finish_t = done
                    req.finish_tick = self.ticks
                    self._fault_event("nan_quarantined", id=req.id)
                    self._fault_event("failed_requests", id=req.id,
                                      reason=req.error)
                    continue
                req.state = fe.DONE
                req.result = out[i]
                req.admit_t, req.finish_t = start, done
                req.admit_tick, req.finish_tick = start_tick, self.ticks
                results[req.id] = req.result
                self._log_request(
                    id=req.id,
                    latency_ms=(done - req.submit_t) * 1e3,
                    queue_wait_ms=(start - req.submit_t) * 1e3,
                    decode_ms=(done - start) * 1e3,
                    latency_ticks=self.ticks - req.submit_tick,
                    bucket=bucket,
                    batch_fill=b / bucket,
                )
        return results

    # ------------------------------------------------------------- reporting

    def serving_bytes(self) -> int:
        """HBM bytes of the serving params (kneaded packed or bf16 floats)."""
        total = 0
        kinds = (KneadedWeight, ShardedKneadedWeight)
        for leaf in jax.tree.leaves(self.params,
                                    is_leaf=lambda x: isinstance(x, kinds)):
            if isinstance(leaf, kinds):
                total += leaf.packed_bytes()
            else:
                total += leaf.size * 2          # floats serve as bf16
        return total

    def _layer_codes(self, name: str, kw) -> Optional[jax.Array]:
        """Integer codes of one layer for the cycle model.

        From the retained float checkpoint when present (cheap re-quantize);
        otherwise reconstructed exactly from the packed planes — identical
        on the logical region, since alignment padding quantizes to all-zero
        codes without disturbing the per-channel scales.  Sharded engines
        without the float checkpoint skip cycle stats (the planes live
        device-sharded; gathering them to count bits defeats the point of
        dropping the checkpoint).
        """
        if self.float_params is not None:
            return quantize(self.float_params[name]["w"], bits=kw.bits,
                            axis=-1).q
        if isinstance(kw, KneadedWeight):
            return kneaded_codes(kw)[:kw.logical_k, :kw.logical_n]
        return None

    def layer_report(self, cycle_ks: int = 16) -> List[Dict[str, Any]]:
        """Per-layer kneaded footprint + cycle stats (Fig 9/11 companions).

        ``cycle_ks`` is the *hardware* kneading stride of the cycle model
        (the paper sweeps 10..32) — independent of the storage-format stride
        ``scfg.ks`` that sizes the kernel's K tiles.  Codes come from the
        float checkpoint when retained, else from the packed planes (see
        :meth:`_layer_codes`); ``cycle_ratio`` is None when neither is
        available.  Sharded engines add ``shard_work`` (executed MXU passes
        per device) and ``shard_imbalance`` (max/mean) columns.
        """
        if self.scfg.impl == "float":
            raise ValueError("layer_report needs kneaded params "
                             "(impl != 'float')")
        rows = []
        for name, p in self.params.items():
            kw = p["w"]
            row = {
                "layer": name,
                "shape": (kw.logical_k, kw.logical_n),
                "bytes_vs_bf16": kw.packed_bytes() / kw.dense_bf16_bytes(),
                "cycle_ratio": None,
            }
            if isinstance(kw, ShardedKneadedWeight):
                imb = kw.imbalance()
                row.update({
                    "executed_tile_dots": kw.total_work,
                    "dense_tile_dots": kw.dense_work(),
                    "shard_work": imb["shard_work"],
                    "shard_imbalance": imb["imbalance"],
                })
            else:
                sched = kw.schedule
                # compacted-schedule accounting: MXU passes the pallas path
                # executes per M-step vs what the dense grid would have run
                row.update({
                    "executed_tile_dots": sched.total_work,
                    "dense_tile_dots": sched.dense_work(kw.bits),
                })
            q = self._layer_codes(name, kw)
            if q is not None:
                k = (q.shape[0] // cycle_ks) * cycle_ks
                row["cycle_ratio"] = float(
                    kneading_ratio(q[:k], kw.bits, cycle_ks))
            rows.append(row)
        return rows
