"""CNN serving engine — the paper's own workload, served fully kneaded.

``CNNServingEngine`` is the CNN sibling of the LM ``ServingEngine``: it takes
a trained float checkpoint of an AlexNet/VGG-16/NiN-style model, converts
every conv/fc layer to the kneaded bit-plane format (conv layers via their
im2col [C*kh*kw, out_ch] matrices, zero-padded to tile alignment), and runs
the whole forward pass through the selected SAC execution path:

  impl="float"   — original float weights, plain f32 matmuls (the baseline)
  impl="int"     — integer-code matmul, scale in the epilogue (production CPU)
  impl="planes"  — paper-faithful per-plane SAC (the kernel's semantic oracle)
  impl="pallas"  — the schedule-compacted Pallas kernel (interpret on CPU,
                   compiled on TPU): each conv layer is ONE pallas_call whose
                   grid streams all activation rows and executes only the
                   work items of the layer's KneadedSchedule — built once
                   here at engine init (inside knead) and stored on each
                   KneadedWeight

"planes" and "pallas" are bit-exact against each other; all kneaded paths
match the float model within the quantization error bound.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.core.kneading import KneadedWeight, kneading_ratio
from repro.core.quantization import quantize
from repro.core.sac import SAC_IMPLS
from repro.models import cnn

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CNNServingConfig:
    impl: str = "int"          # "float" | "int" | "planes" | "pallas"
    bits: int = 8              # kneaded fixed-point width
    ks: int = 256              # kneading stride == kernel K tile
    n_block: int = 128         # kernel N tile (occupancy/schedule granularity)
    jit: bool = True
    # Retain the float checkpoint after kneading so layer_report() can
    # derive cycle statistics cheaply.  Set False for long-lived serving
    # processes that only need the forward pass — the kneaded params alone
    # then realize the advertised ~bits/16 memory footprint in-process.
    keep_float_params: bool = True


class CNNServingEngine:
    """Classify images through a fully-kneaded CNN forward pass."""

    def __init__(self, cfg: cnn.CNNConfig, params: PyTree,
                 scfg: CNNServingConfig = CNNServingConfig()):
        if scfg.impl not in SAC_IMPLS:
            raise ValueError(f"impl must be one of {SAC_IMPLS}, "
                             f"got {scfg.impl!r}")
        self.cfg, self.scfg = cfg, scfg
        if scfg.impl == "float":
            self.params = params
            self.float_params = params
        else:
            self.params = cnn.knead_params(params, bits=scfg.bits,
                                           ks=scfg.ks, n_block=scfg.n_block)
            self.float_params = params if scfg.keep_float_params else None

        def fwd(p, x):
            return cnn.apply(p, x, cfg, impl=scfg.impl)

        self._fwd = jax.jit(fwd) if scfg.jit else fwd

    def logits(self, x: jax.Array) -> jax.Array:
        """x [B, H, W, C] -> logits [B, num_classes]."""
        return self._fwd(self.params, x)

    def classify(self, x: jax.Array) -> jax.Array:
        """x [B, H, W, C] -> predicted class ids [B] int32."""
        return jnp.argmax(self.logits(x), axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------- reporting

    def serving_bytes(self) -> int:
        """HBM bytes of the serving params (kneaded packed or bf16 floats)."""
        total = 0
        for leaf in jax.tree.leaves(self.params,
                                    is_leaf=lambda x: isinstance(
                                        x, KneadedWeight)):
            if isinstance(leaf, KneadedWeight):
                total += leaf.packed_bytes()
            else:
                total += leaf.size * 2          # floats serve as bf16
        return total

    def layer_report(self, cycle_ks: int = 16) -> List[Dict[str, Any]]:
        """Per-layer kneaded footprint + cycle stats (Fig 9/11 companions).

        ``cycle_ks`` is the *hardware* kneading stride of the cycle model
        (the paper sweeps 10..32) — independent of the storage-format stride
        ``scfg.ks`` that sizes the kernel's K tiles.  Codes come from
        re-quantizing the retained float checkpoint (identical to the
        kneaded codes on the logical region, without unpacking the
        [B-1, K, N] bit planes of every layer just to count them).
        """
        if self.scfg.impl == "float":
            raise ValueError("layer_report needs kneaded params "
                             "(impl != 'float')")
        if self.float_params is None:
            raise ValueError("layer_report needs the float checkpoint; "
                             "construct with keep_float_params=True")
        rows = []
        for name, p in self.params.items():
            kw = p["w"]
            q = quantize(self.float_params[name]["w"], bits=kw.bits,
                         axis=-1).q
            k = (q.shape[0] // cycle_ks) * cycle_ks
            sched = kw.schedule
            rows.append({
                "layer": name,
                "shape": (kw.logical_k, kw.logical_n),
                "bytes_vs_bf16": kw.packed_bytes() / kw.dense_bf16_bytes(),
                "cycle_ratio": float(kneading_ratio(q[:k], kw.bits, cycle_ks)),
                # compacted-schedule accounting: MXU passes the pallas path
                # executes per M-step vs what the dense grid would have run
                "executed_tile_dots": sched.total_work,
                "dense_tile_dots": sched.dense_work(kw.bits),
            })
        return rows
