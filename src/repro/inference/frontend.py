"""Shared batched-request front-end plumbing for the serving engines.

``CNNServingEngine`` (images) and ``ServingEngine`` (LM prompts) expose the
same ``submit()``/``drain()``/``latency_stats()`` surface; what differs is
the payload and how a micro-batch executes.  This mixin owns the parts that
must never diverge between them: bucket validation, request-id/pending
bookkeeping, the sliding per-request log, and the latency summary.  Each
engine keeps its own ``submit``/``drain`` (shape checks and micro-batch
execution are engine-specific) and records served requests through
:meth:`_log_request`.
"""
from __future__ import annotations

import collections
from typing import Any, Deque, Dict, List, Sequence, Tuple


def validate_buckets(buckets: Sequence[int]) -> None:
    """Padding buckets must be positive and ascending (drain pads a chunk
    up to the smallest bucket that fits, so order is load-bearing)."""
    if tuple(buckets) != tuple(sorted(buckets)) or \
            not all(b > 0 for b in buckets):
        raise ValueError(f"buckets must be positive ascending, "
                         f"got {tuple(buckets)}")


class RequestFrontEnd:
    """Mixin: request bookkeeping + latency accounting for submit/drain."""

    _next_id: int
    _pending: List[Tuple]
    _request_log: Deque[Dict[str, Any]]

    def _init_front_end(self, stats_window: int) -> None:
        self._next_id = 0
        self._pending = []
        self._request_log = collections.deque(maxlen=stats_window)

    def _log_request(self, **entry: Any) -> None:
        self._request_log.append(entry)

    def latency_stats(self) -> Dict[str, float]:
        """Per-request latency distribution over the last ``stats_window``
        drained requests (a sliding window, bounded by construction)."""
        import numpy as np

        lat = np.array([r["latency_ms"] for r in self._request_log])
        if lat.size == 0:
            return {"requests": 0}
        fill = np.array([r["batch_fill"] for r in self._request_log])
        return {
            "requests": int(lat.size),
            "mean_ms": float(lat.mean()),
            "p50_ms": float(np.percentile(lat, 50)),
            "p95_ms": float(np.percentile(lat, 95)),
            "max_ms": float(lat.max()),
            "mean_batch_fill": float(fill.mean()),
        }
