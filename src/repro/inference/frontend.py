"""Shared request front-end plumbing for the serving engines.

``CNNServingEngine`` (images) and ``ServingEngine`` (LM prompts) expose the
same request surface; what differs is the payload and how requests execute.
This module owns the parts that must never diverge between them:

* :class:`Request` — one submitted unit of work and its lifecycle state
  machine (``queued -> running -> done`` with ``cancelled``/``expired``
  exits; docs/DESIGN.md §9).
* :class:`RequestHandle` — what ``submit()`` returns.  It subclasses
  ``int`` so every pre-handle call site keeps working (the handle *is*
  the request id: sortable, hashable, ``==`` against plain ints, usable
  as the ``drain()`` dict key), while the redesigned API rides along:
  ``result()`` blocks until this request finishes, ``stream()`` yields
  tokens as they are generated, ``cancel()`` withdraws the request, and
  ``priority``/``deadline`` expose the admission fields.
* :class:`RequestFrontEnd` — bucket validation, id/pending bookkeeping,
  the virtual-launch clock (``ticks``), the sliding per-request log, and
  the latency summary with its queue-wait vs decode-time breakdown.

Each engine keeps its own ``submit``/``drain`` (payload checks and
execution are engine-specific) and records served requests through
:meth:`RequestFrontEnd._log_request`.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import (Any, Deque, Dict, Iterator, List, Optional,
                    Sequence)

import numpy as np

# Request lifecycle states (docs/DESIGN.md §9 state machine; FAILED added
# by the resilience layer, docs/DESIGN.md §10)
QUEUED = "queued"        # submitted, waiting for admission
RUNNING = "running"      # admitted to a slot (continuous) / being drained
DONE = "done"            # all tokens produced
CANCELLED = "cancelled"  # withdrawn by cancel()
EXPIRED = "expired"      # deadline passed before admission
FAILED = "failed"        # terminal: retries exhausted (fault policy)

TERMINAL = (DONE, CANCELLED, EXPIRED, FAILED)


class DeadlineExceeded(RuntimeError):
    """result() on a request whose deadline lapsed before admission."""


class RequestFailed(RuntimeError):
    """result() on a request that exhausted its fault-policy retries."""


def validate_buckets(buckets: Sequence[int]) -> None:
    """Padding buckets must be non-empty, positive and ascending (drain
    and the admission batcher pad a chunk up to the smallest bucket that
    fits, so order is load-bearing)."""
    if not buckets:
        raise ValueError("buckets must be a non-empty ascending tuple")
    if tuple(buckets) != tuple(sorted(buckets)) or \
            not all(b > 0 for b in buckets):
        raise ValueError(f"buckets must be positive ascending, "
                         f"got {tuple(buckets)}")


@dataclasses.dataclass
class Request:
    """One submitted request and its lifecycle bookkeeping.

    ``payload`` is engine-specific (a 1-D token prompt for the LM engine,
    an [H, W, C] image for the CNN engine).  Wall-clock stamps
    (``submit_t``/``admit_t``/``finish_t``) feed ``latency_stats``;
    the ``*_tick`` twins are stamped from the engine's deterministic
    virtual-launch clock so benches can compare schedulers bit-for-bit.
    """

    id: int
    payload: Any
    num_tokens: int = 0
    priority: int = 0
    deadline: Optional[float] = None      # seconds from submit; None = never
    state: str = QUEUED
    out: List[int] = dataclasses.field(default_factory=list)
    result: Optional[np.ndarray] = None
    slot: Optional[int] = None
    submit_t: float = 0.0
    admit_t: float = 0.0
    finish_t: float = 0.0
    submit_tick: int = 0
    admit_tick: int = 0
    finish_tick: int = 0
    # resilience bookkeeping (docs/DESIGN.md §10): recovery attempts so
    # far, the wall-clock instant before which admission must not retry
    # (exponential-backoff window), and the terminal failure reason.
    retries: int = 0
    retry_at: float = 0.0
    error: Optional[str] = None

    @property
    def prompt_len(self) -> int:
        return int(getattr(self.payload, "shape", (0,))[0])

    def expired(self, now: float) -> bool:
        return (self.state == QUEUED and self.deadline is not None
                and now - self.submit_t > self.deadline)


class RequestHandle(int):
    """``submit()``'s return value: the request id, plus the request API.

    Subclasses ``int`` so code written against the old id-returning
    ``submit()`` — ``sorted(handles)``, ``results[handle]``,
    ``handle == 3`` — is untouched, while new call sites get
    ``result()/stream()/cancel()`` and the admission fields.
    """

    _req: Request
    _engine: "RequestFrontEnd"

    def __new__(cls, req: Request, engine: "RequestFrontEnd"):
        h = super().__new__(cls, req.id)
        h._req = req
        h._engine = engine
        return h

    @property
    def state(self) -> str:
        return self._req.state

    @property
    def priority(self) -> int:
        return self._req.priority

    @property
    def deadline(self) -> Optional[float]:
        return self._req.deadline

    @property
    def retries(self) -> int:
        """Recovery attempts consumed so far (fault policy; §10)."""
        return self._req.retries

    @property
    def error(self) -> Optional[str]:
        """Terminal failure reason once the request is FAILED."""
        return self._req.error

    def tokens_so_far(self) -> np.ndarray:
        """Tokens generated so far (without blocking)."""
        return np.asarray(self._req.out, dtype=np.int32)

    def result(self) -> np.ndarray:
        """Block until this request finishes; returns its output tokens
        (LM) or logits (CNN).  Raises on cancel/deadline expiry."""
        return self._engine._result(self._req)

    def stream(self) -> Iterator[int]:
        """Yield output tokens as they are generated.  Under the
        continuous scheduler tokens arrive per decode step; under the
        batch scheduler the request is drained first and then replayed
        token-by-token (degenerate streaming, same contract)."""
        return self._engine._stream(self._req)

    def cancel(self) -> bool:
        """Withdraw the request.  True if it was still cancellable
        (queued, or mid-decode under the continuous scheduler — its KV
        blocks are freed immediately); False once done."""
        return self._engine._cancel(self._req)


class RequestFrontEnd:
    """Mixin: request bookkeeping + latency accounting for the engines."""

    _next_id: int
    _pending: List[Request]
    _requests: Dict[int, Request]
    _request_log: Deque[Dict[str, Any]]
    ticks: int

    def _init_front_end(self, stats_window: int) -> None:
        self._next_id = 0
        self._pending = []
        self._requests = {}
        self._request_log = collections.deque(maxlen=stats_window)
        # Virtual-launch clock: +1 per jitted prefill/decode/forward
        # launch.  Deterministic (unlike wall time), so scheduler benches
        # gate latency-in-ticks in CI (bench_kernels serving_load_sweep).
        self.ticks = 0
        # Resilience telemetry (docs/DESIGN.md §10): monotonic counters
        # (retries, failed_requests, nan_quarantined, recoveries,
        # watchdog_timeouts, straggler_steps, degradations, ...) merged
        # into latency_stats(), plus a bounded event log of the notable
        # transitions (recoveries, impl demotions, integrity repairs).
        self._fault_counters: collections.Counter = collections.Counter()
        self._fault_events: Deque[Dict[str, Any]] = collections.deque(
            maxlen=stats_window)
        # Activation-skip accounting baseline (docs/DESIGN.md §12): the
        # counters are process-wide (they accumulate from inside jitted
        # decode steps via debug callback), so each engine snapshots at
        # construction and latency_stats() reports its own delta.
        from repro.core import activation_occupancy
        self._skip_stats_base = activation_occupancy.skip_stats()
        # MoE routing-load accounting (docs/DESIGN.md §13): same process-
        # global counter pattern — snapshot at construction, report deltas.
        from repro.core import routing_stats
        self._routing_stats_base = routing_stats.routing_stats()

    def _fault_event(self, kind: str, **detail: Any) -> None:
        self._fault_counters[kind] += 1
        self._fault_events.append({"kind": kind, "tick": self.ticks,
                                   **detail})

    def fault_events(self) -> List[Dict[str, Any]]:
        """Notable resilience transitions (bounded sliding window)."""
        return list(self._fault_events)

    def _new_request(self, payload: Any, num_tokens: int = 0, *,
                     priority: int = 0,
                     deadline: Optional[float] = None) -> RequestHandle:
        req = Request(id=self._next_id, payload=payload,
                      num_tokens=num_tokens, priority=priority,
                      deadline=deadline, submit_t=time.perf_counter(),
                      submit_tick=self.ticks)
        self._next_id += 1
        self._requests[req.id] = req
        self._pending.append(req)
        return RequestHandle(req, self)

    def _log_request(self, **entry: Any) -> None:
        self._request_log.append(entry)

    # ---- handle backends: batch-path defaults (drain serves everything).
    # ServingEngine overrides these when the continuous scheduler is on.

    def _finished_result(self, req: Request) -> np.ndarray:
        if req.state == CANCELLED:
            raise RuntimeError(f"request {req.id} was cancelled")
        if req.state == EXPIRED:
            raise DeadlineExceeded(
                f"request {req.id} missed its deadline "
                f"({req.deadline:.3f}s) before admission")
        if req.state == FAILED:
            raise RequestFailed(
                f"request {req.id} failed after {req.retries} retries: "
                f"{req.error}")
        assert req.state == DONE, req
        return req.result

    def _result(self, req: Request) -> np.ndarray:
        if req.state in (QUEUED, RUNNING):
            self.drain()
        return self._finished_result(req)

    def _stream(self, req: Request) -> Iterator[int]:
        out = self._result(req)
        yield from (int(t) for t in np.asarray(out).reshape(-1))

    def _cancel(self, req: Request) -> bool:
        if req.state != QUEUED:
            return False
        req.state = CANCELLED
        self._pending = [r for r in self._pending if r.id != req.id]
        return True

    # ------------------------------------------------------------- stats

    def latency_stats(self) -> Dict[str, float]:
        """Per-request latency distribution over the last ``stats_window``
        served requests (a sliding window, bounded by construction).

        Beyond total latency, the summary breaks out **queue wait**
        (submit -> start of execution) vs **decode time** (execution
        start -> completion) at p50/p95 each, so the batch and continuous
        schedulers are comparable from the CLI: batch mode hides its
        wave barrier in queue wait, continuous in slightly longer decode
        (shared slots).
        """
        lat = np.array([r["latency_ms"] for r in self._request_log])
        if lat.size == 0:
            return {"requests": 0,
                    **{k: int(v) for k, v in self._fault_counters.items()
                       if v},
                    **self._skip_stats_delta(),
                    **self._routing_stats_delta()}
        out = {
            "requests": int(lat.size),
            "mean_ms": float(lat.mean()),
            "p50_ms": float(np.percentile(lat, 50)),
            "p95_ms": float(np.percentile(lat, 95)),
            "max_ms": float(lat.max()),
        }
        fill = [r["batch_fill"] for r in self._request_log
                if "batch_fill" in r]
        if fill:
            out["mean_batch_fill"] = float(np.mean(fill))
        for key, label in (("queue_wait_ms", "queue_wait"),
                           ("decode_ms", "decode")):
            vals = np.array([r[key] for r in self._request_log if key in r])
            if vals.size:
                out[f"{label}_p50_ms"] = float(np.percentile(vals, 50))
                out[f"{label}_p95_ms"] = float(np.percentile(vals, 95))
        # resilience counters (docs/DESIGN.md §10): zero-valued keys are
        # omitted — a fault-free engine's stats look exactly as before
        out.update({k: int(v) for k, v in self._fault_counters.items() if v})
        # activation-skip accounting (docs/DESIGN.md §12): present only
        # when masked launches actually ran under this engine
        out.update(self._skip_stats_delta())
        # MoE routing load (docs/DESIGN.md §13): present only when routed
        # MoE layers actually ran under this engine
        out.update(self._routing_stats_delta())
        return out

    def _skip_stats_delta(self) -> Dict[str, float]:
        """This engine's activation-skip traffic since construction:
        ``executed_tile_dots``, ``weight_tile_dots`` and the derived
        ``act_skip_frac`` — empty when no masked launch ran (skip off),
        so stats dicts are unchanged for skip-off engines."""
        from repro.core import activation_occupancy
        cur = activation_occupancy.skip_stats()
        weight = (cur["weight_tile_dots"]
                  - self._skip_stats_base["weight_tile_dots"])
        if weight <= 0:
            return {}
        executed = (cur["executed_tile_dots"]
                    - self._skip_stats_base["executed_tile_dots"])
        return {"executed_tile_dots": int(executed),
                "weight_tile_dots": int(weight),
                "act_skip_frac": float(1.0 - executed / weight)}

    def _routing_stats_delta(self) -> Dict[str, int]:
        """This engine's MoE routing load since construction: per-step
        routed (token, expert) assignment counts and capacity-overflow
        drops — empty when no MoE layer ran, so stats dicts are unchanged
        for dense engines."""
        from repro.core import routing_stats
        cur = routing_stats.routing_stats()
        steps = cur["routing_steps"] - self._routing_stats_base["routing_steps"]
        if steps <= 0:
            return {}
        return {"routed_tokens": int(cur["routed_tokens"]
                                     - self._routing_stats_base["routed_tokens"]),
                "capacity_dropped": int(
                    cur["capacity_dropped"]
                    - self._routing_stats_base["capacity_dropped"]),
                "routing_steps": int(steps)}

