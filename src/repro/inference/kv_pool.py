"""Block-granular KV-cache pool for the continuous-batching scheduler.

The batch-synchronous ``drain()`` path pads every request's KV cache to
``max_len`` and pays for that padding on *every* decode step: attention
reads the full extent whether the longest in-flight request needs 16
positions or 512.  The pool breaks that coupling (docs/DESIGN.md §9):

* KV capacity is a shared budget of fixed-size **blocks** (``block``
  tokens each).  A request is admitted only when the pool can reserve
  ``ceil((prompt + budget) / block)`` blocks — admission control is a
  *token* budget, not just a slot count, so many short requests can be
  in flight where few long ones would fit.
* Each in-flight slot owns a **block table** (the physical block ids
  reserved for it).  On this container the tables drive accounting and
  the per-step compute extent; on a TPU the same tables are what a paged
  attention kernel would consume to gather non-contiguous blocks.
* :meth:`extent` is the pool's high-water mark — the largest allocated
  per-slot extent, in whole blocks.  The scheduler sizes its jitted
  decode step to this extent instead of ``max_len``, so a step's
  attention cost tracks the *longest live request* (rounded up to a
  block) and shrinks when long requests retire.  Block-multiple extents
  keep the jit compile cache bounded: at most ``max_len / block``
  decode-step shapes per slot capacity.

Reservation is up front (prompt + full token budget at admission), so a
running request can never hit pool exhaustion mid-decode — there is no
preemption/swap path to get wrong.  The cost is admitting slightly
conservatively; the paper-faithful analogy is a Tetris schedule that
reserves its worst-case lane depth at dispatch time.
"""
from __future__ import annotations

from typing import Dict, List


class PoolExhausted(RuntimeError):
    """Raised when a reservation is attempted beyond the pool budget."""


class KVBlockPool:
    """Fixed budget of KV blocks shared by the scheduler's slots.

    ``block`` is the allocation granularity in tokens (0 selects one
    block spanning ``max_len`` — the degenerate "dense row" pool).
    ``total_tokens`` caps the shared budget; 0 sizes the pool so every
    slot can hold a full ``max_len`` request (the un-constrained
    default — admission then limited by slots alone).
    """

    def __init__(self, num_slots: int, max_len: int, block: int = 0,
                 total_tokens: int = 0) -> None:
        if num_slots < 1 or max_len < 1:
            raise ValueError(f"need num_slots/max_len >= 1, got "
                             f"{num_slots}/{max_len}")
        self.block = min(block, max_len) if block > 0 else max_len
        self.max_len = max_len
        self.blocks_per_request_max = -(-max_len // self.block)
        budget = total_tokens or num_slots * max_len
        self.total_blocks = max(1, -(-budget // self.block))
        self._free: List[int] = list(range(self.total_blocks))
        self._tables: Dict[int, List[int]] = {}

    # ------------------------------------------------------------ queries

    def blocks_needed(self, n_tokens: int) -> int:
        return -(-max(1, n_tokens) // self.block)

    def fits(self, n_tokens: int) -> bool:
        """Could a request of ``n_tokens`` EVER be admitted (empty pool)?
        Submit-time validation uses this for a clear early error."""
        return (n_tokens <= self.max_len
                and self.blocks_needed(n_tokens) <= self.total_blocks)

    def can_admit(self, n_tokens: int) -> bool:
        return self.blocks_needed(n_tokens) <= len(self._free)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.total_blocks - len(self._free)

    def utilization(self) -> float:
        return self.used_blocks / self.total_blocks

    def block_table(self, slot: int) -> List[int]:
        return list(self._tables.get(slot, ()))

    def slot_extent(self, slot: int) -> int:
        """Allocated token extent of one slot (whole blocks)."""
        return len(self._tables.get(slot, ())) * self.block

    def extent(self) -> int:
        """High-water compute extent over live slots, in whole blocks,
        capped at ``max_len`` (the scheduler's decode-step seq extent)."""
        if not self._tables:
            return 0
        return min(self.max_len,
                   max(len(t) for t in self._tables.values()) * self.block)

    # -------------------------------------------------------- reservations

    def alloc(self, slot: int, n_tokens: int) -> List[int]:
        """Reserve blocks for ``n_tokens`` on ``slot``; returns the table."""
        if slot in self._tables:
            raise ValueError(f"slot {slot} already holds a reservation")
        need = self.blocks_needed(n_tokens)
        if need > len(self._free):
            raise PoolExhausted(
                f"{need} blocks needed, {len(self._free)} free "
                f"(of {self.total_blocks})")
        table, self._free = self._free[:need], self._free[need:]
        self._tables[slot] = table
        return list(table)

    def free(self, slot: int) -> int:
        """Release a slot's reservation; returns the block count freed.
        (Free list kept sorted so reuse patterns are deterministic.)"""
        table = self._tables.pop(slot, None)
        if table is None:
            return 0
        self._free = sorted(self._free + table)
        return len(table)

    def release_all(self) -> int:
        """Release every reservation; returns the block count freed.

        The engine-step recovery path (docs/DESIGN.md §10) rebuilds the
        slot table from scratch — surviving requests re-reserve at
        re-admission — so the pool must drop all tables at once rather
        than trust per-slot bookkeeping that a mid-step exception may
        have left half-updated.
        """
        freed = sum(len(t) for t in self._tables.values())
        self._tables.clear()
        self._free = list(range(self.total_blocks))
        return freed
