"""Serving-side resilience: fault policy, injection, and weight integrity.

The training loop has had bounded-retry restarts and straggler detection
since the seed (``runtime/fault_tolerance.py``); the serving stack had
none — an exception in one jitted decode step killed every in-flight
request, one NaN logit poisoned its whole micro-batch, and nothing
integrity-checked the kneaded planes or schedule arrays whose corruption
silently changes *which work items execute* (the flip side of the kneaded
form being an exact re-encoding).  This module is the serving half of the
fault story (docs/DESIGN.md §10):

* :class:`ServingFaultPolicy` — the knob set carried on ``ServingConfig``:
  bounded per-request retries with capped exponential backoff, the
  per-decode-step watchdog (timeout + straggler watermark, built on
  :class:`~repro.runtime.fault_tolerance.StepTimer`), the NaN/Inf logit
  quarantine guard, and the graceful-degradation ladder that demotes the
  engine impl ``pallas -> planes -> float`` after repeated kernel faults.
* :class:`EngineFaultInjector` — deterministic chaos hooks for tests and
  the ``serving_fault_sweep`` bench, extending the training-loop
  :class:`~repro.runtime.fault_tolerance.FailureInjector` idea to the
  engine's step loop: injected step exceptions, per-request NaN logits,
  and simulated slot (device-row) loss, all keyed on step/request ids so
  every chaos run replays identically.
* Weight corruption + verification helpers — flip bits in a kneaded
  weight's planes/presence/schedule arrays (for chaos tests), and
  :func:`verify_kneaded_tree` to sweep a serving param tree against its
  knead-time checksums, repairing corrupt leaves by re-kneading from the
  float checkpoint (:func:`~repro.core.kneading.reknead_like`).

Recovery is **bit-exact by replay**: greedy decode is deterministic and
per-row independent, so a request re-admitted after a fault — re-prefilled
on its original prompt and re-decoded step by step — regenerates exactly
the tokens it had already produced and continues identically to a
fault-free run.  (Recovery deliberately does NOT re-prefill
``prompt + generated-prefix`` as one longer sequence: changing a matmul's
M extent changes the f32 reduction order on real backends, which would
break the bitwise guarantee the schedulers are tested against.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.kneading import (KneadedWeight, ShardedKneadedWeight,
                                 reknead_like)
from repro.runtime import fault_tolerance as ft

PyTree = Any

__all__ = [
    "EngineFaultInjector",
    "InjectedKernelFault",
    "ServingFaultPolicy",
    "StepTimeout",
    "corrupt_array_word",
    "corrupt_kneaded",
    "verify_kneaded_tree",
]


class InjectedKernelFault(ft.InjectedFailure):
    """Deterministically injected engine/kernel step failure."""


class StepTimeout(RuntimeError):
    """A watchdogged decode step exceeded ``step_timeout_s``."""


@dataclasses.dataclass
class EngineFaultInjector:
    """Deterministic fault plan for the serving engine's step loop.

    All hooks key on the scheduler's step counter or on request ids, so a
    chaos run is exactly reproducible.  ``fail_once`` mirrors the training
    injector: each step-indexed fault fires once (the recovery path then
    gets a clean retry); NaN poisoning keys on request id and fires on
    *every* launch that request participates in (modelling persistent bad
    state — the request must exhaust its retries and FAIL), unless
    ``nan_once`` is set (transient glitch — the retry succeeds).
    """

    # indices into the scheduler's decode/prefill launch-ATTEMPT counters
    # (failed attempts advance them too, so consecutive indices model a
    # fault streak and a lone index a transient glitch)
    fail_decode_steps: Tuple[int, ...] = ()
    fail_prefill_steps: Tuple[int, ...] = ()
    nan_request_ids: Tuple[int, ...] = ()
    nan_once: bool = False
    # simulated loss of one slot's device state: (step, slot) pairs
    lose_slot_steps: Tuple[Tuple[int, int], ...] = ()
    fail_once: bool = True

    def __post_init__(self):
        self._decode = ft.FailureInjector(self.fail_decode_steps,
                                          fail_once=self.fail_once)
        self._prefill = ft.FailureInjector(self.fail_prefill_steps,
                                           fail_once=self.fail_once)
        self._nan_pending = set(self.nan_request_ids)
        self._loss_pending = set(self.lose_slot_steps)

    def maybe_fail_decode(self, step: int) -> None:
        try:
            self._decode.maybe_fail(step)
        except ft.InjectedFailure as exc:
            raise InjectedKernelFault(
                f"injected kernel fault at decode step {step}") from exc

    def maybe_fail_prefill(self, step: int) -> None:
        try:
            self._prefill.maybe_fail(step)
        except ft.InjectedFailure as exc:
            raise InjectedKernelFault(
                f"injected kernel fault at prefill step {step}") from exc

    def poison_request(self, request_id: int) -> bool:
        """Should this request's logits row be NaN-poisoned this launch?"""
        if request_id not in self._nan_pending:
            return False
        if self.nan_once:
            self._nan_pending.discard(request_id)
        return True

    def lost_slots(self, step: int) -> List[int]:
        """Slots whose device state is 'lost' at this step (fires once)."""
        hits = [s for (t, s) in self._loss_pending if t == step]
        for s in hits:
            self._loss_pending.discard((step, s))
        return hits


@dataclasses.dataclass
class ServingFaultPolicy:
    """Fault handling for the serving engines (docs/DESIGN.md §10).

    Attached to ``ServingConfig(fault_policy=...)``.  ``None`` (the
    default) keeps the pre-resilience behavior exactly: no guards, no
    recovery, exceptions propagate.

    Attributes:
      max_retries:      recovery attempts per request before the terminal
                        ``FAILED`` state (counts NaN quarantines, slot
                        losses, and engine-step failures alike).
      retry_backoff_s / backoff_mult / backoff_cap_s: per-request
                        exponential backoff window between retries —
                        admission skips a request until its window passes.
      step_timeout_s:   watchdog threshold on one decode launch (0 = off).
                        A jitted step cannot be preempted mid-flight, so
                        the watchdog detects *after* the launch returns:
                        it counts ``watchdog_timeouts``, and with
                        ``timeout_is_fault`` treats the step as failed
                        (the recovery path re-admits in-flight work).
      straggler_k:      :class:`~repro.runtime.fault_tolerance.StepTimer`
                        watermark — steps beyond median + k*MAD count as
                        ``straggler_steps`` in ``latency_stats()``.
      nan_guard:        check prefill/decode logits rows for NaN/Inf and
                        quarantine ONLY the offending request (requeue or
                        FAIL), never the batch.  Costs one host fetch of
                        the logits per launch — leave on; disable only for
                        benchmarking the guard itself.
      demote_after:     consecutive engine-step faults before the impl
                        demotes one rung down ``fallback_impls``
                        (pallas -> planes stays bit-exact; planes ->
                        float trades exactness for availability and is
                        logged as a degradation event).
      fallback_impls:   the degradation ladder, strongest-first.
      verify_weights:   verify kneaded-weight checksums at engine init
                        (restored/transported params; corrupt leaves are
                        re-kneaded from the float checkpoint, which the
                        engine still holds at init time).
      injector:         deterministic chaos hooks (tests/bench only).
    """

    max_retries: int = 2
    retry_backoff_s: float = 0.02
    backoff_mult: float = 2.0
    backoff_cap_s: float = 1.0
    step_timeout_s: float = 0.0
    timeout_is_fault: bool = False
    straggler_k: float = 5.0
    nan_guard: bool = True
    demote_after: int = 2
    fallback_impls: Tuple[str, ...] = ("planes", "float")
    verify_weights: bool = False
    injector: Optional[EngineFaultInjector] = None

    def backoff_for(self, retries: int) -> float:
        """Backoff window before retry number ``retries`` (1-based)."""
        raw = self.retry_backoff_s * (self.backoff_mult ** max(0,
                                                               retries - 1))
        return min(raw, self.backoff_cap_s)


# ---------------------------------------------------------------- corruption


def corrupt_array_word(x, flat_index: int = 0, xor: int = 1):
    """Return a copy of ``x`` with one word XOR-flipped (chaos helper)."""
    arr = np.asarray(x).copy()
    flat = arr.reshape(-1)
    if np.issubdtype(arr.dtype, np.integer):
        flat[flat_index] ^= xor
    else:
        flat[flat_index] = flat[flat_index] + 1.0
    return jnp.asarray(arr)


_CORRUPTIBLE = {
    "planes": "planes",
    "signs": "signs",
    "occupancy": "occupancy",
    "schedule.counts": "counts",
    "schedule.plane_ids": "plane_ids",
    "schedule.ktile_ids": "ktile_ids",
}


def corrupt_kneaded(kw: KneadedWeight, field: str = "occupancy",
                    flat_index: int = 0, xor: int = 1) -> KneadedWeight:
    """Flip one word of a kneaded weight's array ``field`` (dotted names
    reach into the schedule).  The result fails ``verify()`` on exactly
    that field — checksums are deliberately NOT re-stamped."""
    if field not in _CORRUPTIBLE:
        raise ValueError(f"field must be one of {sorted(_CORRUPTIBLE)}, "
                         f"got {field!r}")
    if field.startswith("schedule."):
        leaf = field.split(".", 1)[1]
        sched = kw.schedule
        new_sched = dataclasses.replace(
            sched, **{leaf: corrupt_array_word(getattr(sched, leaf),
                                               flat_index, xor)})
        return dataclasses.replace(kw, schedule=new_sched)
    return dataclasses.replace(
        kw, **{field: corrupt_array_word(getattr(kw, field),
                                         flat_index, xor)})


# ----------------------------------------------------------- tree integrity


def verify_kneaded_tree(params: PyTree, float_params: Optional[PyTree] = None,
                        *, shards: int = 0, repair: bool = True,
                        ) -> Tuple[PyTree, List[Dict[str, Any]]]:
    """Sweep a serving param tree for corrupted kneaded leaves.

    Every :class:`KneadedWeight` / :class:`ShardedKneadedWeight` leaf is
    verified against its knead-time checksums.  With ``repair`` and a
    ``float_params`` tree of the same structure (the engine's pre-knead
    checkpoint), corrupt leaves are rebuilt in place via
    :func:`~repro.core.kneading.reknead_like` — deterministic, so the
    repaired leaf is bit-identical to the never-corrupted one.

    Returns ``(maybe-repaired tree, report)`` where each report row is
    ``{"path", "fields", "repaired"}`` for one corrupt leaf (empty report
    = tree intact).  Raises
    :class:`~repro.core.schedule.KneadedIntegrityError` when a corrupt
    leaf cannot be repaired (no float source).
    """
    import jax

    from repro.core.schedule import KneadedIntegrityError

    kinds = (KneadedWeight, ShardedKneadedWeight)
    is_kw = lambda x: isinstance(x, kinds)            # noqa: E731
    flat, treedef = jax.tree_util.tree_flatten_with_path(params,
                                                         is_leaf=is_kw)
    floats = {}
    if float_params is not None:
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                float_params)[0]:
            floats[jax.tree_util.keystr(path)] = leaf
    report: List[Dict[str, Any]] = []
    out = []
    for path, leaf in flat:
        if not is_kw(leaf):
            out.append(leaf)
            continue
        bad = leaf.verify()
        if not bad:
            out.append(leaf)
            continue
        key = jax.tree_util.keystr(path)
        src = floats.get(key)
        if repair and src is not None:
            leaf = reknead_like(leaf, src, shards=shards)
            report.append({"path": key, "fields": bad, "repaired": True})
        else:
            report.append({"path": key, "fields": bad, "repaired": False})
        out.append(leaf)
    unrepaired = [r for r in report if not r["repaired"]]
    if unrepaired:
        raise KneadedIntegrityError(
            "corrupt kneaded weights with no float source to re-knead "
            f"from: {[(r['path'], r['fields']) for r in unrepaired]}")
    return jax.tree_util.tree_unflatten(treedef, out), report
