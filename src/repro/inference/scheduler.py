"""Continuous-batching decode scheduler for the LM serving engine.

The batch-synchronous ``drain()`` path serves requests in waves: a
micro-batch prefills together, decodes together for the chunk-max token
budget, and nothing new is admitted until the wave retires.  Under load
that wave barrier is exactly the ineffectual work Tetris compacts out of
the MXU: decode steps spent on rows that are finished, padded, or not
yet admitted.  :class:`ContinuousScheduler` removes the barrier at step
granularity (docs/DESIGN.md §9):

* **Slot table** — a fixed capacity of ``max_inflight`` in-flight rows.
  Each scheduler step admits queued prompts into free slots (one padded
  prefill launch, interleaved with decode), runs ONE decode launch for
  every live slot, appends each live request's next token, and retires
  finished requests immediately — their slots and KV blocks free the
  same step, so the next admission can reuse them.
* **KV block pool** (:class:`~repro.inference.kv_pool.KVBlockPool`) —
  admission reserves ``prompt + budget`` tokens of block-granular KV up
  front; the jitted decode step is shaped to the pool's high-water
  extent (largest live reservation, rounded to a block) instead of
  ``max_len``, so short-request traffic stops paying long-request
  attention costs.
* **Compile-cache buckets** — the padding-bucket machinery of the batch
  path becomes the compile-cache layer underneath: the decode batch dim
  pads to the smallest slot-capacity bucket covering the highest live
  slot, and prefill pads to the smallest bucket covering the admission
  group, so jit sees one decode shape per (slot bucket, block extent)
  and one prefill shape per (bucket, prompt length).

Bit-exactness: every per-row computation (masked cache writes, per-row
positions, attention masked to ``<= pos``) is row-independent, and
greedy selection is invariant to the batch rows around it and to the
padded cache extent beyond the mask — so a request's generation here is
bit-identical to the batch path's ``generate()`` (regression-tested for
the planes and pallas impls in tests/test_scheduler.py).

Fault handling (docs/DESIGN.md §10; off unless the engine carries a
:class:`~repro.inference.resilience.ServingFaultPolicy`): the step loop
wraps in a recovery path — an engine-step exception requeues every
in-flight request (bounded per-request retries with backoff, then the
terminal ``FAILED`` state), rebuilds the slot table and KV pool from
scratch, and replays survivors from their prompt.  Replay is bit-exact
for greedy decode: the same row-independence that makes the scheduler
match ``generate()`` makes a re-admitted request regenerate exactly the
prefix it had already produced.  A NaN/Inf logit guard quarantines only
the offending request's row; a :class:`~repro.runtime.fault_tolerance
.StepTimer` watchdog flags slow/stuck decode launches; repeated step
faults demote the engine impl down its fallback ladder.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.inference import frontend as fe
from repro.inference.kv_pool import KVBlockPool
from repro.inference.resilience import StepTimeout
from repro.runtime import fault_tolerance as ft

PyTree = Any

# Cache keys with a sequence axis and their pad values (mirrors
# ServingEngine._pad_cache: KV stores zero-pad, int8-KV scales pad 1.0
# so dequantization of masked lanes stays finite).  Name-keyed on the
# model families' cache dicts — never shape-sniffed (the zamba2 hybrid
# lesson, see _pad_cache's docstring).
_SEQ_PAD = {"k": 0.0, "v": 0.0, "k_scale": 1.0, "v_scale": 1.0}


class ContinuousScheduler:
    """Step-level slot scheduler over a ServingEngine's jitted model fns.

    The engine owns params, jitted prefill/decode, token selection and
    the request front end; the scheduler owns the slot table, the KV
    pool, and the per-step admit -> decode -> retire loop.
    """

    def __init__(self, engine) -> None:
        self.eng = engine
        scfg = engine.scfg
        self.capacity = scfg.max_inflight
        self.pool = KVBlockPool(scfg.max_inflight, scfg.max_len,
                                block=scfg.kv_block,
                                total_tokens=scfg.kv_pool_tokens)
        # slot -> running Request (fixed table; None = free)
        self.slots: List[Optional[fe.Request]] = [None] * self.capacity
        # batch-dim compile-cache buckets, clipped to the slot capacity
        bks = [b for b in scfg.buckets if b < self.capacity]
        self.slot_buckets: Tuple[int, ...] = tuple(bks) + (self.capacity,)
        self._cache: Optional[PyTree] = None
        self._batch = 0            # current cache batch dim (a slot bucket)
        self._extent = 0           # current cache seq extent (block multiple)
        self._axes: Dict[str, Tuple[int, Optional[int]]] = \
            self._detect_axes(engine.model)
        self._key = jax.random.PRNGKey(0)
        # resilience (docs/DESIGN.md §10): None = pre-resilience behavior
        self.policy = getattr(scfg, "fault_policy", None)
        self._timer = (ft.StepTimer(k=self.policy.straggler_k)
                       if self.policy is not None else None)
        self._step_idx = 0         # scheduler steps (slot-loss injection key)
        # launch ATTEMPTS, counted before the launch so a failed one still
        # advances — a one-shot injected fault index then hits exactly once
        self._decode_calls = 0     # decode attempts (injection/watchdog key)
        self._prefill_calls = 0    # prefill attempts (injection key)
        self._fault_streak = 0     # consecutive failed steps (demotion gate)

    # ----------------------------------------------------- cache geometry

    @staticmethod
    def _detect_axes(model) -> Dict[str, Tuple[int, Optional[int]]]:
        """Per-cache-leaf (batch_axis, seq_axis) from cache_spec diffs.

        Axes are found by varying one spec argument at a time and
        diffing shapes — robust across families (stacked [L, B, ...]
        leaves, SSM states with no seq axis at all) without hardcoding
        layouts beyond what the model itself reports.
        """
        b1 = model.cache_spec(batch=1, max_len=16)
        b2 = model.cache_spec(batch=2, max_len=16)
        s2 = model.cache_spec(batch=1, max_len=32)
        axes = {}
        for key in b1:
            d_b = [i for i, (a, b) in enumerate(zip(b1[key].shape,
                                                    b2[key].shape)) if a != b]
            d_s = [i for i, (a, b) in enumerate(zip(b1[key].shape,
                                                    s2[key].shape)) if a != b]
            assert len(d_b) == 1, f"cache[{key}]: ambiguous batch axis {d_b}"
            assert len(d_s) <= 1, f"cache[{key}]: ambiguous seq axis {d_s}"
            # store seq axis negative so it survives batch-rank differences
            ndim = len(b1[key].shape)
            seq = (d_s[0] - ndim) if d_s else None
            axes[key] = (d_b[0] - ndim, seq)
        return axes

    def _resize_leaf(self, x: jax.Array, key: str, batch: int,
                     extent: int) -> jax.Array:
        """Pad/slice one cache leaf to (batch, extent) on its own axes."""
        b_ax, s_ax = self._axes[key]
        for ax, target, value in ((b_ax, batch, 0.0),
                                  (s_ax, extent, _SEQ_PAD.get(key, 0.0))):
            if ax is None:
                continue
            cur = x.shape[ax]
            if target > cur:
                pads = [(0, 0)] * x.ndim
                pads[ax] = (0, target - cur)
                x = jnp.pad(x, pads, constant_values=value)
            elif target < cur:
                idx = [slice(None)] * x.ndim
                idx[ax] = slice(0, target)
                x = x[tuple(idx)]
        return x

    def _resize_cache(self) -> None:
        """Track the slot-bucket batch dim and the pool's high-water
        extent; shrink when retirements lower either (the compile cache
        then reuses the smaller step)."""
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            self._cache, self._batch, self._extent = None, 0, 0
            return
        batch = next(b for b in self.slot_buckets if b >= max(live) + 1)
        extent = self.pool.extent()
        if (batch, extent) == (self._batch, self._extent):
            return
        self._cache = {k: self._resize_leaf(v, k, batch, extent)
                       for k, v in self._cache.items()}
        self._batch, self._extent = batch, extent

    def _write_slot(self, slot: int, row_cache: PyTree, plen: int) -> None:
        """Copy one prefilled request (batch row 0 of ``row_cache``) into
        ``slot`` of the live cache, padded out to the current extent."""
        for key, leaf in self._cache.items():
            b_ax, _ = self._axes[key]
            row = self._resize_leaf(row_cache[key], key, 1, self._extent)
            idx = [slice(None)] * leaf.ndim
            idx[b_ax] = slot
            row_idx = [slice(None)] * row.ndim
            row_idx[b_ax] = 0
            self._cache[key] = leaf.at[tuple(idx)].set(row[tuple(row_idx)])

    # ------------------------------------------------------------- stepping

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def _live(self) -> List[Tuple[int, fe.Request]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _expire(self) -> None:
        now = time.perf_counter()
        expired = [r for r in self.eng._pending if r.expired(now)]
        if expired:
            for r in expired:
                r.state = fe.EXPIRED
            self.eng._pending = [r for r in self.eng._pending
                                 if r.state == fe.QUEUED]

    def _admission_group(self) -> List[fe.Request]:
        """Pick this step's prefill group: queued requests in strict
        (priority desc, id asc) order; the head request sets the prompt
        length (one prefill shape per launch) and same-length followers
        join up to the free-slot / bucket / KV-pool / prefill-chunk caps."""
        free = self._free_slots()
        if not free or not self.eng._pending:
            return []
        # retried requests wait out their backoff window before re-admission
        now = time.perf_counter()
        queue = sorted((r for r in self.eng._pending if r.retry_at <= now),
                       key=lambda r: (-r.priority, r.id))
        cap = min(len(free), self.slot_buckets[-1],
                  self.eng.scfg.buckets[-1])
        chunk = self.eng.scfg.prefill_chunk
        group: List[fe.Request] = []
        budget_tokens = 0
        # simulate pool reservations so the group stays admissible jointly
        need = 0
        for r in queue:
            if group and r.prompt_len != group[0].prompt_len:
                continue      # next step's head may pick this length
            tokens = r.prompt_len + r.num_tokens
            if len(group) == cap:
                break
            if chunk and group and budget_tokens + r.prompt_len > chunk:
                break
            if self.pool.blocks_needed(tokens) + need > self.pool.free_blocks:
                if not group:
                    continue  # head doesn't fit yet; try a smaller request
                break
            group.append(r)
            need += self.pool.blocks_needed(tokens)
            budget_tokens += r.prompt_len
        return group

    def _admit(self) -> None:
        group = self._admission_group()
        if not group:
            return
        ids = {r.id for r in group}
        self.eng._pending = [r for r in self.eng._pending
                             if r.id not in ids]
        plen = group[0].prompt_len
        bucket = next(b for b in self.eng.scfg.buckets if b >= len(group))
        now = time.perf_counter()
        for r in group:
            r.slot = self._free_slots()[0]
            self.pool.alloc(r.slot, plen + r.num_tokens)
            self.slots[r.slot] = r
            r.state = fe.RUNNING
            r.admit_t, r.admit_tick = now, self.eng.ticks
        toks = jnp.stack([r.payload for r in group])
        if bucket > len(group):
            toks = jnp.pad(toks, ((0, bucket - len(group)), (0, 0)))
        # attempt counter advances BEFORE the launch (fault included), so
        # a retried step moves past a one-shot injected fault index
        attempt = self._prefill_calls
        self._prefill_calls += 1
        if self.policy is not None and self.policy.injector is not None:
            # after slot/pool assignment, so recovery sees the group live
            self.policy.injector.maybe_fail_prefill(attempt)
        with self.eng._mesh_ctx():
            logits, pre_cache = self.eng._prefill(self.eng.params,
                                                  {"tokens": toks})
        self.eng.ticks += 1
        logits, bad_rows = self._guard_logits(logits, group)
        tok0 = np.asarray(self.eng._select(logits, self._next_key()))
        # grow the live cache geometry BEFORE inserting the new rows
        if self._cache is None:
            extent = self.pool.extent()
            batch = next(b for b in self.slot_buckets
                         if b >= max(r.slot for r in group) + 1)
            spec = self.eng.model.cache_spec(batch=batch, max_len=extent)
            self._cache = {k: jnp.zeros(v.shape, v.dtype)
                           for k, v in spec.items()}
            for key, pad in _SEQ_PAD.items():
                if key in self._cache and pad != 0.0:
                    self._cache[key] = jnp.full(
                        self._cache[key].shape, pad,
                        self._cache[key].dtype)
            self._batch, self._extent = batch, extent
        else:
            self._resize_cache()
        for i, r in enumerate(group):
            if i in bad_rows:
                self.eng._fault_event("nan_quarantined", id=r.id,
                                      at="prefill")
                self._requeue_or_fail(r, "non-finite logits at prefill")
                continue
            r.out.append(int(tok0[i]))
            if len(r.out) >= r.num_tokens:
                self._retire(r)       # single-token request: done at prefill
            else:
                row = {k: jnp.take(v, jnp.array([i]), axis=self._axes[k][0])
                       for k, v in pre_cache.items()}
                self._write_slot(r.slot, row, plen)
        self._resize_cache()          # a same-step retirement may shrink

    def _retire(self, req: fe.Request) -> None:
        self.slots[req.slot] = None
        self.pool.free(req.slot)
        req.slot = None
        req.state = fe.DONE
        req.result = np.asarray(req.out, dtype=np.int32)
        req.finish_t = time.perf_counter()
        req.finish_tick = self.eng.ticks
        live = sum(r is not None for r in self.slots) + 1
        self.eng._log_request(
            id=req.id,
            latency_ms=(req.finish_t - req.submit_t) * 1e3,
            queue_wait_ms=(req.admit_t - req.submit_t) * 1e3,
            decode_ms=(req.finish_t - req.admit_t) * 1e3,
            latency_ticks=req.finish_tick - req.submit_tick,
            queue_wait_ticks=req.admit_tick - req.submit_tick,
            bucket=self._batch or live,
            batch_fill=live / self.capacity,
            prompt_len=req.prompt_len,
            decode_tokens=req.num_tokens,
        )

    def _decode_once(self) -> None:
        live = self._live()
        if not live:
            return
        pol = self.policy
        attempt = self._decode_calls       # advances even on a failed
        self._decode_calls += 1            # launch — see _admit
        if pol is not None and pol.injector is not None:
            pol.injector.maybe_fail_decode(attempt)
        b = self._batch
        tok = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        for slot, r in live:
            tok[slot, 0] = r.out[-1]
            pos[slot] = r.prompt_len + len(r.out) - 1
        if self._timer is not None:
            self._timer.start()
        with self.eng._mesh_ctx():
            logits, self._cache = self.eng._decode(
                self.eng.params, jnp.asarray(tok), jnp.asarray(pos),
                self._cache)
        self.eng.ticks += 1
        if self._timer is not None:
            # the launch is async; time to logits-ready, which the token
            # select below forces anyway
            jax.block_until_ready(logits)
            flagged = len(self._timer.straggler_steps)
            dt = self._timer.stop(attempt)
            if len(self._timer.straggler_steps) > flagged:
                self.eng._fault_counters["straggler_steps"] += 1
            if pol.step_timeout_s and dt > pol.step_timeout_s:
                self.eng._fault_event("watchdog_timeouts",
                                      step=attempt, dt_s=dt)
                if pol.timeout_is_fault:
                    # before any token lands: recovery replays the whole
                    # step, so no request observes a half-applied step
                    raise StepTimeout(
                        f"decode launch {attempt} took "
                        f"{dt:.3f}s > step_timeout_s={pol.step_timeout_s}")
        rows: List[Optional[fe.Request]] = [None] * b
        for slot, r in live:
            rows[slot] = r
        logits, bad_rows = self._guard_logits(logits, rows)
        nxt = np.asarray(self.eng._select(logits, self._next_key()))
        for slot, r in live:
            if slot in bad_rows:
                # row-independence makes surviving rows' cache writes
                # valid; only this request's state is junk
                self.eng._fault_event("nan_quarantined", id=r.id,
                                      at="decode")
                self._requeue_or_fail(r, "non-finite logits at decode")
                continue
            r.out.append(int(nxt[slot]))
            if len(r.out) >= r.num_tokens:
                self._retire(r)
        self._resize_cache()

    # --------------------------------------- fault handling (§10; policy)

    def _guard_logits(self, logits, rows: List[Optional[fe.Request]]):
        """NaN/Inf quarantine + deterministic poison injection.

        ``rows[i]`` is the request owning logits row ``i`` (None for
        padding).  Returns ``(logits, bad_rows)`` where ``bad_rows`` are
        the indices whose request must be quarantined; their rows are
        zeroed so the batch's token select stays well-defined (the
        quarantined requests never consume the selected token).
        """
        pol = self.policy
        if pol is None:
            return logits, set()
        inj = pol.injector
        poison = [i for i, r in enumerate(rows)
                  if r is not None and inj is not None
                  and inj.poison_request(r.id)]
        if not pol.nan_guard and not poison:
            return logits, set()
        host = np.asarray(logits).copy()
        for i in poison:
            host[i] = np.nan
        bad_rows: set = set()
        if pol.nan_guard:
            for i, r in enumerate(rows):
                if r is not None and not np.isfinite(
                        host[i].astype(np.float32)).all():
                    bad_rows.add(i)
                    host[i] = 0.0
        return jnp.asarray(host), bad_rows

    def _requeue_or_fail(self, req: fe.Request, reason: str) -> None:
        """Bounded-retry recovery for one request: free its slot/KV, then
        either requeue it for full replay (with an exponential-backoff
        window) or mark it terminally FAILED.

        Replay restarts from the prompt (``out`` resets): re-prefilling
        ``prompt + generated-prefix`` as one longer sequence would change
        the matmul M extent and with it the f32 reduction order, breaking
        bit-exactness (see core/sac.py).  Greedy replay regenerates the
        identical prefix, so ``stream()`` consumers — whose emitted
        counter simply stalls until ``out`` regrows — never see a torn or
        divergent token sequence.
        """
        if req.slot is not None:
            self.slots[req.slot] = None
            self.pool.free(req.slot)
            req.slot = None
        req.retries += 1
        if req.retries > self.policy.max_retries:
            req.state = fe.FAILED
            req.error = reason
            req.finish_t = time.perf_counter()
            req.finish_tick = self.eng.ticks
            self.eng._fault_event("failed_requests", id=req.id,
                                  reason=reason, retries=req.retries - 1)
            return
        req.out = []
        req.state = fe.QUEUED
        req.retry_at = (time.perf_counter()
                        + self.policy.backoff_for(req.retries))
        self.eng._fault_event("retries", id=req.id, reason=reason,
                              attempt=req.retries)
        if all(p.id != req.id for p in self.eng._pending):
            self.eng._pending.append(req)

    def _lose_slots(self) -> None:
        """Injected device-state loss: the slot's cache rows are junk, so
        the owning request replays; everything else is untouched."""
        pol = self.policy
        if pol is None or pol.injector is None:
            return
        hit = False
        for slot in pol.injector.lost_slots(self._step_idx):
            r = self.slots[slot] if slot < len(self.slots) else None
            if r is not None:
                self.eng._fault_event("slot_losses", id=r.id, slot=slot)
                self._requeue_or_fail(r, f"slot {slot} device state lost")
                hit = True
        if hit:
            self._resize_cache()

    def _recover(self, exc: Exception) -> None:
        """Engine-step failure: requeue-or-fail every in-flight request
        and rebuild the execution state from scratch.

        The decode jit donates the cache (``donate_argnums``), so a
        launch that raised may have invalidated it — nothing step-level
        is salvageable.  The slot table, KV pool, and live cache all
        reset; surviving requests re-admit through the normal path and
        replay bit-exactly (see :meth:`_requeue_or_fail`).  Repeated
        faults demote the engine impl down the policy's fallback ladder
        (pallas -> planes preserves bit-exactness; planes -> float trades
        it for availability).
        """
        self._fault_streak += 1
        self.eng._fault_event("recoveries",
                              error=f"{type(exc).__name__}: {exc}",
                              streak=self._fault_streak)
        for _, r in self._live():
            self._requeue_or_fail(r, f"engine step failed: "
                                     f"{type(exc).__name__}: {exc}")
        self.slots = [None] * self.capacity
        self.pool.release_all()     # a mid-step exception may have left
        self._cache = None          # per-slot bookkeeping half-updated
        self._batch = self._extent = 0
        if self._fault_streak >= self.policy.demote_after:
            if self.eng._demote_impl(
                    f"{self._fault_streak} consecutive step faults "
                    f"(last: {type(exc).__name__})"):
                self._fault_streak = 0

    def _maybe_wait_backoff(self) -> None:
        """With nothing in flight and every queued request inside its
        backoff window, sleep to the earliest retry so the step loop
        stays productive instead of spinning."""
        if any(r is not None for r in self.slots) or not self.eng._pending:
            return
        wait = min(r.retry_at for r in self.eng._pending) \
            - time.perf_counter()
        if wait > 0:
            time.sleep(min(wait, self.policy.backoff_cap_s))

    def step(self) -> bool:
        """One scheduler step: expire -> admit (one prefill group) -> one
        decode launch over the slot table -> retire.  Returns True if any
        request is still queued or in flight.

        With a fault policy, the step body runs under the recovery
        umbrella: any exception requeues in-flight work (bounded retries,
        then FAILED) and rebuilds the slot table — the loop itself never
        dies to a step fault.
        """
        if self.policy is None:
            self._expire()
            self._admit()
            self._decode_once()
        else:
            try:
                self._expire()
                self._lose_slots()
                self._admit()
                self._decode_once()
                self._fault_streak = 0     # clean step: demotion de-arms
            except Exception as exc:  # noqa: BLE001 — any step fault
                self._recover(exc)         # enters bounded recovery
            self._step_idx += 1
            self._maybe_wait_backoff()
        return bool(self.eng._pending or any(r is not None
                                             for r in self.slots))

    def cancel(self, req: fe.Request) -> bool:
        if req.state == fe.QUEUED:
            req.state = fe.CANCELLED
            self.eng._pending = [r for r in self.eng._pending
                                 if r.id != req.id]
            return True
        if req.state == fe.RUNNING:
            # mid-decode withdrawal: the slot and its KV blocks free NOW;
            # the abandoned cache rows are masked junk to every other row
            self.slots[req.slot] = None
            self.pool.free(req.slot)
            req.slot = None
            req.state = fe.CANCELLED
            self._resize_cache()
            return True
        return False

    # ----------------------------------------------------------- blocking

    def run_until(self, req: fe.Request) -> None:
        """Step until ``req`` leaves the queued/running states."""
        while req.state in (fe.QUEUED, fe.RUNNING):
            if not self.step():
                break

    def drain(self) -> Dict[int, jax.Array]:
        """Compatibility wrapper: run the step loop until every request
        pending at call time has finished; returns {id: tokens} exactly
        like the batch path (cancelled/expired requests excluded)."""
        wave = ([r for r in self.eng._pending]
                + [r for _, r in self._live()])
        while self.step():
            pass
        return {r.id: jnp.asarray(r.result) for r in wave
                if r.state == fe.DONE}

    def stream(self, req: fe.Request) -> Iterator[int]:
        """Per-token iterator: drives the scheduler only as far as needed
        for the next token of ``req``."""
        emitted = 0
        while True:
            while emitted < len(req.out):
                yield req.out[emitted]
                emitted += 1
            if req.state in fe.TERMINAL:
                # FAILED raises even mid-stream: replay retracted the
                # emitted prefix, so a silent stop would look like a
                # short-but-valid completion
                if req.state == fe.FAILED or \
                        (req.state != fe.DONE and emitted == 0):
                    self.eng._finished_result(req)   # raise the right error
                return
            # a queued/running request always keeps step() productive
            # (queued => pending non-empty), so this cannot spin idle;
            # the retiring step may return False with tokens still
            # unflushed, hence the loop-back before any exit
            self.step()
