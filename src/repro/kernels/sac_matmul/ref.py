"""Pure-jnp oracle for the SAC bit-plane matmul kernel.

The reference computes ``A @ unknead(KW)`` in f32 — by construction exactly
``scale * sum_b 2^b (A @ S_b)`` (see repro.core.sac).  The Pallas kernel must
match this to f32 matmul tolerance for every shape/dtype/bit-width.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kneading import KneadedWeight, unknead


def sac_matmul_ref(a: jax.Array, kw: KneadedWeight) -> jax.Array:
    """[M, K] @ kneaded [K, N] -> [M, N] f32."""
    return jnp.dot(a.astype(jnp.float32), unknead(kw),
                   preferred_element_type=jnp.float32)
