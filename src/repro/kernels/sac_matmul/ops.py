"""Jitted public wrappers for the SAC bit-plane Pallas kernel.

``sac_matmul_pallas``: the raw [M, K] x kneaded [K, N] op — padding/tiling
policy and backend dispatch (compiled Pallas on TPU, ``interpret=True``
elsewhere; this container is CPU-only and interpret mode executes the kernel
body faithfully for validation).  Accepts activations sized to either the
stored (tile-aligned) or the logical reduction dim and zero-pads internally —
padded rows meet all-zero weight rows that the schedule never dispatches.

``sac_conv2d``: the batched convolution entry point — im2col + schedule-
compacted SAC matmul behind **one** ``pallas_call``: the kernel grid's M
dimension streams every activation row of the [B*H'*W', K] patch matrix
through VMEM one [bm, bk] slab per M-step.  No host-side slab loop, no
remainder-shape retraces, no concatenate — a VGG-16-sized patch matrix costs
one launch whose peak VMEM footprint is still a single block.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.kneading import KneadedWeight
from repro.kernels.sac_matmul.kernel import sac_matmul_pallas_call


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("bits", "ks", "n_block", "bm", "interpret"))
def _run(a, planes, signs, scale, schedule, *, bits, ks, n_block, bm,
         interpret):
    return sac_matmul_pallas_call(
        a, planes, signs, scale, schedule,
        bits=bits, bm=bm, bn=n_block, bk=ks,
        interpret=interpret,
    )


def sac_matmul_pallas(
    a: jax.Array,
    kw: KneadedWeight,
    *,
    bm: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """[M, K] @ kneaded [K, N] -> [M, N] f32 via the Pallas SAC kernel.

    M is padded up to the tile size.  K may be either the stored (aligned)
    ``kw.k`` or the logical ``kw.logical_k`` — logical activations are
    zero-padded here, exactly as ``sac_conv2d`` does, so direct FC callers
    need no padding logic of their own.  N alignment is guaranteed by the
    kneaded format (n_block | N); the output keeps the stored N (slice to
    ``kw.logical_n`` at the call site if needed).
    """
    if interpret is None:
        interpret = not _on_tpu()
    m, k = a.shape
    if k != kw.k:
        if k != kw.logical_k:
            raise ValueError(f"activation K {k} matches neither stored "
                             f"{kw.k} nor logical {kw.logical_k}")
        a = jnp.pad(a, ((0, 0), (0, kw.k - k)))
    bm_eff = min(bm, max(8, m))
    pad = (-m) % bm_eff
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
    out = _run(
        a, kw.planes, kw.signs, kw.scale, kw.schedule,
        bits=kw.bits, ks=kw.ks, n_block=kw.n_block, bm=bm_eff,
        interpret=interpret,
    )
    return out[:m] if pad else out


def im2col(x: jax.Array, k: int, stride: int) -> jax.Array:
    """x [B, H, W, C] -> patches [B, H', W', C*k*k] ('SAME' padding).

    The single source of truth for the conv lowering — the float path in
    ``models/cnn.py`` imports this same function, so float and kneaded
    convolutions see identical patch layouts by construction.
    """
    return jax.lax.conv_general_dilated_patches(
        x, (k, k), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def sac_conv2d(
    x: jax.Array,
    kw: KneadedWeight,
    *,
    ksize: int,
    stride: int = 1,
    bias: Optional[jax.Array] = None,
    impl: str = "pallas",
    bm: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """2-D convolution as im2col + SAC matmul against a kneaded filter.

    The filter is the kneaded form of the [C*kh*kw, out_ch] im2col weight
    matrix (use ``knead_padded`` — C*k*k is rarely tile-aligned).  For
    ``impl="pallas"`` the whole [B*H'*W', K] patch matrix goes through a
    *single* ``pallas_call``: the grid's M dimension streams the rows in
    [bm, bk] blocks, so one launch covers the layer and the VMEM-side
    footprint stays one block regardless of image size.  Other impls
    ("planes"/"int"/"float") take the pure-jnp SAC paths — same math, used
    as oracles and fast CPU fallbacks.

    Returns [B, H', W', out_ch] f32 (+ bias if given).
    """
    patches = im2col(x, ksize, stride)                  # [B, H', W', C*k*k]
    lead = patches.shape[:-1]
    a = patches.reshape(-1, patches.shape[-1])
    k0 = a.shape[1]
    if k0 not in (kw.k, kw.logical_k):
        raise ValueError(f"patch K {k0} does not match kneaded weight "
                         f"(stored {kw.k}, logical {kw.logical_k})")
    if impl != "pallas":
        from repro.core.sac import sac_matmul
        out = sac_matmul(a.astype(jnp.float32), kw, impl=impl)
    else:
        out = sac_matmul_pallas(a, kw, bm=bm, interpret=interpret)
        out = out[:, :kw.logical_n]
    out = out.reshape(lead + (kw.logical_n,)).astype(jnp.float32)
    if bias is not None:
        out = out + bias
    return out
