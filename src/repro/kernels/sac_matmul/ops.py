"""Jitted public wrappers for the SAC bit-plane Pallas kernel.

``sac_matmul_pallas``: the raw [M, K] x kneaded [K, N] op — padding/tiling
policy and backend dispatch (compiled Pallas on TPU, ``interpret=True``
elsewhere; this container is CPU-only and interpret mode executes the kernel
body faithfully for validation).  Accepts activations sized to either the
stored (tile-aligned) or the logical reduction dim and zero-pads internally —
padded rows meet all-zero weight rows that the schedule never dispatches.

``sac_conv2d``: the batched convolution entry point — im2col + schedule-
compacted SAC matmul behind **one** ``pallas_call``: the kernel grid's M
dimension streams every activation row of the [B*H'*W', K] patch matrix
through VMEM one [bm, bk] slab per M-step.  No host-side slab loop, no
remainder-shape retraces, no concatenate — a VGG-16-sized patch matrix costs
one launch whose peak VMEM footprint is still a single block.

``sac_matmul_pallas_sharded``: the multi-device form (docs/DESIGN.md §5,
§8) — the same kernel launched under ``jax.shard_map`` over a mesh axis,
one launch per device, each device walking *its own shard's* compacted work
list (a :class:`~repro.core.schedule.ShardedKneadedWeight`, or a per-layer
scan slice of a stacked LM
:class:`~repro.core.schedule.ShardedStackedKneadedWeight`).  Kneaded MoE
expert banks take a different route entirely: whole experts live on the
"expert" mesh axis and each expert's 2-D slice reaches ``sac_matmul_pallas``
through the block-level ``lax.scan`` (docs/DESIGN.md §13) — banks never
enter the sharded N-split entry here.  Activations
are replicated, outputs concatenate along N with no collective in the
matmul itself; per-device executed MXU passes equal that shard's occupancy
nonzeros.  The GEMV decode fast path survives sharding: ``_pad_activations``
shrinks the M block *before* the shard_map, so a batch-1 LM decode step
runs a single 8-row M-step per device rather than a 97%-padding streamed
slab.  ``sac_conv2d``, the FC dispatch, and ``core.sac.sac_matmul`` (the
LM projection entry) all accept sharded weights with a ``mesh``;
``mesh=None`` runs the shards serially on one device — the oracle the
multi-device parity tests compare against.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import activation_occupancy
from repro.core.kneading import KneadedWeight, ShardedKneadedWeight
from repro.core.schedule import KneadedSchedule
from repro.kernels.sac_matmul.kernel import sac_matmul_pallas_call


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("bits", "ks", "n_block", "bm", "interpret"))
def _run(a, planes, signs, scale, schedule, mask, *, bits, ks, n_block, bm,
         interpret):
    return sac_matmul_pallas_call(
        a, planes, signs, scale, schedule,
        bits=bits, bm=bm, bn=n_block, bk=ks,
        interpret=interpret, mask=mask,
    )


def sac_matmul_pallas(
    a: jax.Array,
    kw: KneadedWeight,
    *,
    bm: int = 256,
    interpret: bool | None = None,
    skip_activations: bool = False,
) -> jax.Array:
    """[M, K] @ kneaded [K, N] -> [M, N] f32 via the Pallas SAC kernel.

    M is padded up to the tile size.  K may be either the stored (aligned)
    ``kw.k`` or the logical ``kw.logical_k`` — logical activations are
    zero-padded here, exactly as ``sac_conv2d`` does, so direct FC callers
    need no padding logic of their own.  N alignment is guaranteed by the
    kneaded format (n_block | N); the output keeps the stored N (slice to
    ``kw.logical_n`` at the call site if needed).

    ``skip_activations=True`` arms the two-sided skip (docs/DESIGN.md §12):
    per-K-tile presence bits computed from the (padded) activations are
    intersected into the schedule walk via the kernel's survival mask, so
    real work items whose activation K-slice is all zero never execute an
    MXU pass.  Bit-exact against the unskipped walk — a dropped item would
    have contributed exactly 0.0 to its f32 segment, and surviving items
    keep their k-major order.  ``core.sac.sac_matmul`` gates this to the
    decode-GEMV regime; this raw entry applies it at any M when asked.

    The kernel itself is strictly 2-D: stacked weights (LM layer stacks,
    MoE expert banks — planes ndim > 3) must be sliced to one [K, N]
    kneaded weight per call (``lax.scan`` over the stack axes, as
    ``models.blocks._dispatch_compute_kneaded`` does for expert banks;
    docs/DESIGN.md §13).
    """
    if kw.planes.ndim > 3:
        raise ValueError(
            f"sac_matmul_pallas is a 2-D [K, N] kernel; got stacked planes "
            f"{kw.planes.shape} — scan/index the leading stack axes down to "
            f"one slice first (expert banks: models.blocks."
            f"_dispatch_compute_kneaded, docs/DESIGN.md §13)")
    if interpret is None:
        interpret = not _on_tpu()
    a, m, bm_eff = _pad_activations(a, kw, bm)
    if skip_activations:
        presence = activation_occupancy.ktile_presence(a, kw.ks)
        mask = activation_occupancy.work_mask(
            kw.schedule.counts, kw.schedule.ktile_ids, presence)
        activation_occupancy.record_skip(mask, kw.schedule.counts)
    else:
        mask = activation_occupancy.weight_only_mask(
            kw.schedule.counts, kw.schedule.num_work)
    out = _run(
        a, kw.planes, kw.signs, kw.scale, kw.schedule, mask,
        bits=kw.bits, ks=kw.ks, n_block=kw.n_block, bm=bm_eff,
        interpret=interpret,
    )
    return out[:m]


def m_block(m: int, bm: int = 256) -> int:
    """Effective M block for an M-row launch — the decode/GEMV fast path:
    M rounded up to the 8-row f32 sublane floor, capped at ``bm``.  Shared
    with the planes oracle (``core.sac``), which replays the kernel at the
    same padded M so odd-M launches stay bit-comparable (XLA CPU picks
    different dense-matmul micro-kernels for e.g. M=7 vs M=8 at wide N —
    the same reduction-order sensitivity docs/DESIGN.md §5 records for
    forced host devices)."""
    return min(bm, max(8, -(-m // 8) * 8))


def _pad_activations(a: jax.Array, kw, bm: int):
    """The M/K padding policy shared by the unsharded and sharded entry
    points: accept logical-K activations (zero-pad to the stored dim — the
    padded rows meet all-zero weight rows the schedule never dispatches)
    and round M up to the effective block size.

    The M-block shrinks to fit tiny batches — the decode/GEMV fast path:
    ``bm_eff = min(bm, M rounded up to the 8-row f32 sublane floor)``, so an
    M=1 decode step pads one row to 8 and runs a single M-step instead of
    padding to the full 256-row streaming block (31/32 of every A-tile DMA
    and MXU pass would be padding).  Prefill and conv calls (M >= bm) keep
    the full streamed grid.  ``bm_eff`` is always a multiple of 8, so
    mid-size M (e.g. 12) pads to an aligned single block rather than
    running a misaligned one.
    """
    m, k = a.shape
    if k != kw.k:
        if k != kw.logical_k:
            raise ValueError(f"activation K {k} matches neither stored "
                             f"{kw.k} nor logical {kw.logical_k}")
        a = jnp.pad(a, ((0, 0), (0, kw.k - k)))
    bm_eff = m_block(m, bm)
    pad = (-m) % bm_eff
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
    return a, m, bm_eff


def sac_matmul_pallas_sharded(
    a: jax.Array,
    skw: ShardedKneadedWeight,
    mesh=None,
    axis: str = "model",
    *,
    bm: int = 256,
    interpret: bool | None = None,
    skip_activations: bool = False,
) -> jax.Array:
    """[M, K] @ N-sharded kneaded [K, N] -> [M, N] f32, one kernel per shard.

    With a ``mesh``, runs under ``jax.shard_map`` over ``axis``: activations
    replicated, every weight/schedule array split on its leading shard dim,
    each device launching the SAC kernel on its own compacted work list and
    writing its [M, N/S] output slab — the outputs concatenate along N
    (``out_specs=P(None, axis)``), so the matmul itself needs no collective.
    All shards run the same program: the work-dim extent is the *global*
    ``num_work`` and per-shard ragged tails idle exactly like ragged N-tiles
    do on one device.

    With ``mesh=None``, executes the shards serially on the local device and
    concatenates — bit-identical output (each shard's N-tiles keep their
    single-device work lists and k-major order), used as the parity oracle
    and for host-side analysis without a mesh.

    ``partition="balanced"`` weights (docs/DESIGN.md §11) come out of the
    per-device kernels in *packed slot order* — the LPT bin-packing moved
    whole N-tiles between shards.  The epilogue gathers the [m, n_block]
    output blocks back into original column order through ``skw.tile_slot``
    (``out_tile[j] = packed_tile[tile_slot[j]]``).  Each tile's value was
    produced by the same work items in the same k-major order as on one
    device, so the gathered output is bit-exact against the unsharded
    kernel; for a mesh run the gather is the only cross-shard data movement
    the op introduces.

    Output keeps the sharded stored N (slice to ``skw.logical_n`` at the
    call site, as with the unsharded op).

    ``skip_activations=True``: the activation K-tile presence is computed
    *once* from the replicated (padded) activations — sharding is along N,
    so every shard sees the same presence bits — and intersected with each
    shard's own work list into a per-shard survival mask [S, T, num_work],
    sliced per device alongside the schedule arrays.  The balanced
    partition's ``tile_slot`` gather epilogue is untouched: masking changes
    which items a tile executes, never which shard/slot the tile lives in.
    """
    if interpret is None:
        interpret = not _on_tpu()
    a, m, bm_eff = _pad_activations(a, skw, bm)
    # per-slot survival masks, one row of shards: [S, T, num_work]
    base = jax.lax.broadcasted_iota(
        jnp.int32, skw.ktile_ids.shape, 2) < skw.counts[:, :, None]
    if skip_activations:
        presence = activation_occupancy.ktile_presence(a, skw.ks)
        mask = (base & (presence[skw.ktile_ids] != 0)).astype(jnp.int32)
        activation_occupancy.record_skip(mask, skw.counts)
    else:
        mask = base.astype(jnp.int32)

    def one_shard(a_, planes, signs, scale, counts, pids, kids, mask_):
        # inside shard_map every arg holds this device's slab with the
        # leading shard axis collapsed to extent 1
        sched = KneadedSchedule(
            counts=counts[0], plane_ids=pids[0], ktile_ids=kids[0],
            num_work=skw.num_work, total_work=skw.total_work,
            nk=skw.nk, n_tiles=skw.tiles_per_shard)
        return sac_matmul_pallas_call(
            a_, planes[0], signs[0], scale[0], sched,
            bits=skw.bits, bm=bm_eff, bn=skw.n_block, bk=skw.ks,
            interpret=interpret, mask=mask_[0])

    if mesh is None:
        outs = [one_shard(a, skw.planes[s:s + 1], skw.signs[s:s + 1],
                          skw.scale[s:s + 1], skw.counts[s:s + 1],
                          skw.plane_ids[s:s + 1], skw.ktile_ids[s:s + 1],
                          mask[s:s + 1])
                for s in range(skw.num_shards)]
        out = jnp.concatenate(outs, axis=1)
    else:
        sharded = (P(axis),) * 7
        out = shard_map(
            one_shard, mesh=mesh, in_specs=(P(),) + sharded,
            out_specs=P(None, axis), check_rep=False,
        )(a, skw.planes, skw.signs, skw.scale, skw.counts,
          skw.plane_ids, skw.ktile_ids, mask)
    if skw.partition == "balanced":
        tiles = out.reshape(out.shape[0], -1, skw.n_block)
        out = jnp.take(tiles, skw.tile_slot, axis=1
                       ).reshape(out.shape[0], -1)
    return out[:m]


def im2col(x: jax.Array, k: int, stride: int) -> jax.Array:
    """x [B, H, W, C] -> patches [B, H', W', C*k*k] ('SAME' padding).

    The single source of truth for the conv lowering — the float path in
    ``models/cnn.py`` imports this same function, so float and kneaded
    convolutions see identical patch layouts by construction.
    """
    return jax.lax.conv_general_dilated_patches(
        x, (k, k), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def sac_conv2d(
    x: jax.Array,
    kw,
    *,
    ksize: int,
    stride: int = 1,
    bias: Optional[jax.Array] = None,
    impl: str = "pallas",
    bm: int = 256,
    mesh=None,
    axis: str = "model",
    interpret: bool | None = None,
) -> jax.Array:
    """2-D convolution as im2col + SAC matmul against a kneaded filter.

    The filter is the kneaded form of the [C*kh*kw, out_ch] im2col weight
    matrix (use ``knead_padded`` — C*k*k is rarely tile-aligned).  For
    ``impl="pallas"`` the whole [B*H'*W', K] patch matrix goes through a
    *single* ``pallas_call``: the grid's M dimension streams the rows in
    [bm, bk] blocks, so one launch covers the layer and the VMEM-side
    footprint stays one block regardless of image size.  Other impls
    ("planes"/"int"/"float") take the pure-jnp SAC paths — same math, used
    as oracles and fast CPU fallbacks.

    A :class:`~repro.core.schedule.ShardedKneadedWeight` filter routes
    through :func:`sac_matmul_pallas_sharded` (one kernel launch per mesh
    device, each walking its own shard's work list; ``mesh=None`` = serial
    oracle).  Sharded weights are a Pallas-path artifact, so ``impl`` must
    be "pallas" for them.

    Returns [B, H', W', out_ch] f32 (+ bias if given).
    """
    patches = im2col(x, ksize, stride)                  # [B, H', W', C*k*k]
    lead = patches.shape[:-1]
    a = patches.reshape(-1, patches.shape[-1])
    k0 = a.shape[1]
    if k0 not in (kw.k, kw.logical_k):
        raise ValueError(f"patch K {k0} does not match kneaded weight "
                         f"(stored {kw.k}, logical {kw.logical_k})")
    if isinstance(kw, ShardedKneadedWeight):
        if impl != "pallas":
            raise ValueError("sharded kneaded weights execute through the "
                             f"Pallas kernel only, got impl={impl!r}")
        out = sac_matmul_pallas_sharded(a, kw, mesh, axis, bm=bm,
                                        interpret=interpret)
        out = out[:, :kw.logical_n]
    elif impl != "pallas":
        from repro.core.sac import sac_matmul
        out = sac_matmul(a.astype(jnp.float32), kw, impl=impl)
    else:
        out = sac_matmul_pallas(a, kw, bm=bm, interpret=interpret)
        out = out[:, :kw.logical_n]
    out = out.reshape(lead + (kw.logical_n,)).astype(jnp.float32)
    if bias is not None:
        out = out + bias
    return out
