"""Jitted public wrappers for the SAC bit-plane Pallas kernel.

``sac_matmul_pallas``: the raw [M, K] x kneaded [K, N] op — padding/tiling
policy and backend dispatch (compiled Pallas on TPU, ``interpret=True``
elsewhere; this container is CPU-only and interpret mode executes the kernel
body faithfully for validation).

``sac_conv2d``: the batched convolution entry point — im2col + occupancy-
skipping SAC matmul behind one op, with the activation rows streamed through
the kernel in bounded slabs so VGG-16-sized [B*H'*W', K] patch matrices never
materialize a single huge kernel launch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.kneading import KneadedWeight
from repro.kernels.sac_matmul.kernel import sac_matmul_pallas_call


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("bits", "ks", "n_block", "bm", "interpret"))
def _run(a, planes, signs, scale, occupancy, *, bits, ks, n_block, bm, interpret):
    return sac_matmul_pallas_call(
        a, planes, signs, scale, occupancy,
        bits=bits, bm=bm, bn=n_block, bk=ks,
        interpret=interpret,
    )


def sac_matmul_pallas(
    a: jax.Array,
    kw: KneadedWeight,
    *,
    bm: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """[M, K] @ kneaded [K, N] -> [M, N] f32 via the Pallas SAC kernel.

    M is padded up to the tile size; K/N alignment is guaranteed by the
    kneaded format (ks | K, n_block | N).
    """
    if interpret is None:
        interpret = not _on_tpu()
    m, k = a.shape
    assert k == kw.k, (k, kw.k)
    bm_eff = min(bm, max(8, m))
    pad = (-m) % bm_eff
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
    out = _run(
        a, kw.planes, kw.signs, kw.scale, kw.occupancy,
        bits=kw.bits, ks=kw.ks, n_block=kw.n_block, bm=bm_eff,
        interpret=interpret,
    )
    return out[:m] if pad else out


def im2col(x: jax.Array, k: int, stride: int) -> jax.Array:
    """x [B, H, W, C] -> patches [B, H', W', C*k*k] ('SAME' padding).

    The single source of truth for the conv lowering — the float path in
    ``models/cnn.py`` imports this same function, so float and kneaded
    convolutions see identical patch layouts by construction.
    """
    return jax.lax.conv_general_dilated_patches(
        x, (k, k), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def sac_conv2d(
    x: jax.Array,
    kw: KneadedWeight,
    *,
    ksize: int,
    stride: int = 1,
    bias: Optional[jax.Array] = None,
    impl: str = "pallas",
    m_tile: int = 2048,
    bm: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """2-D convolution as im2col + SAC matmul against a kneaded filter.

    The filter is the kneaded form of the [C*kh*kw, out_ch] im2col weight
    matrix (use ``knead_padded`` — C*k*k is rarely tile-aligned).  For
    ``impl="pallas"`` the [B*H'*W', K] activation rows are streamed through
    the kernel in slabs of ``m_tile`` rows: each slab is one pallas_call, so
    peak VMEM-side footprint is bounded by the slab, not the image.  Other
    impls ("planes"/"int"/"float") take the pure-jnp SAC paths — same math,
    used as oracles and fast CPU fallbacks.

    Returns [B, H', W', out_ch] f32 (+ bias if given).
    """
    patches = im2col(x, ksize, stride)                  # [B, H', W', C*k*k]
    lead = patches.shape[:-1]
    a = patches.reshape(-1, patches.shape[-1])
    k0 = a.shape[1]
    if k0 not in (kw.k, kw.logical_k):
        raise ValueError(f"patch K {k0} does not match kneaded weight "
                         f"(stored {kw.k}, logical {kw.logical_k})")
    if impl != "pallas":
        from repro.core.sac import sac_matmul
        out = sac_matmul(a.astype(jnp.float32), kw, impl=impl)
    else:
        if k0 != kw.k:
            a = jnp.pad(a, ((0, 0), (0, kw.k - k0)))
        m = a.shape[0]
        slabs = []
        for s in range(0, m, m_tile):                   # activation-batch tiling
            slab = a[s:min(s + m_tile, m)]
            # bm passes through unchanged: sac_matmul_pallas clamps it to
            # min(bm, max(8, m)) itself, keeping the sublane dim >= the f32
            # (8, 128) tile floor even for a tiny remainder slab
            slabs.append(sac_matmul_pallas(slab, kw, bm=bm,
                                           interpret=interpret))
        out = slabs[0] if len(slabs) == 1 else jnp.concatenate(slabs, axis=0)
        out = out[:, :kw.logical_n]
    out = out.reshape(lead + (kw.logical_n,)).astype(jnp.float32)
    if bias is not None:
        out = out + bias
    return out
