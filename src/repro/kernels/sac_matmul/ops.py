"""Jitted public wrapper for the SAC bit-plane Pallas kernel.

Handles padding/tiling policy and backend dispatch: compiled Pallas on TPU,
``interpret=True`` elsewhere (this container is CPU-only; interpret mode
executes the kernel body faithfully for validation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.kneading import KneadedWeight
from repro.kernels.sac_matmul.kernel import sac_matmul_pallas_call


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("bits", "ks", "n_block", "bm", "interpret"))
def _run(a, planes, signs, scale, occupancy, *, bits, ks, n_block, bm, interpret):
    return sac_matmul_pallas_call(
        a, planes, signs, scale, occupancy,
        bits=bits, bm=bm, bn=n_block, bk=ks,
        interpret=interpret,
    )


def sac_matmul_pallas(
    a: jax.Array,
    kw: KneadedWeight,
    *,
    bm: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """[M, K] @ kneaded [K, N] -> [M, N] f32 via the Pallas SAC kernel.

    M is padded up to the tile size; K/N alignment is guaranteed by the
    kneaded format (ks | K, n_block | N).
    """
    if interpret is None:
        interpret = not _on_tpu()
    m, k = a.shape
    assert k == kw.k, (k, kw.k)
    bm_eff = min(bm, max(8, m))
    pad = (-m) % bm_eff
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
    out = _run(
        a, kw.planes, kw.signs, kw.scale, kw.occupancy,
        bits=kw.bits, ks=kw.ks, n_block=kw.n_block, bm=bm_eff,
        interpret=interpret,
    )
    return out[:m] if pad else out
