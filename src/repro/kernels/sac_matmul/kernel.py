"""Pallas TPU kernel: SAC bit-plane matmul on a compacted work schedule.

Hardware mapping of the paper's PE (Fig 5) onto the TPU memory hierarchy:

  throttle buffer + pass marks  -> :class:`~repro.core.schedule.KneadedSchedule`
                                   — the occupancy map compacted at knead time
                                   into per-N-tile work lists of non-empty
                                   (plane, K-tile) items, delivered via scalar
                                   prefetch (SMEM).  The grid walks the lists,
                                   so slack work is never *dispatched*, rather
                                   than dispatched-and-predicated-away
  splitter array                -> in-VMEM unpack of the one bit-packed plane
                                   the current work item names (32 weights/
                                   uint32 word) + sign application
  16x16 segment adder fabric    -> one MXU dot per scheduled work item
  segment registers S0..S15     -> VMEM scratch accumulator [B-1, bm, bn] f32,
                                   indexed by the item's plane id
  rear adder tree (shift once)  -> epilogue ``sum_b 2^b * S_b`` executed once
                                   per output tile at the last work step
  per-channel scale             -> applied once in the same epilogue (SAC's
                                   "no intermediate pair-wise partial sums")

Grid: ``(M/bm, N/bn, num_work)`` with the *work list* innermost (revisiting =
output-stationary).  ``num_work`` is the max per-N-tile work count; tile j
executes exactly its surviving mask entries as MXU passes and idles through
the rest — padded schedule entries repeat the tile's last real item, so their
index maps request already-resident blocks and Pallas elides the DMA.  The
guard consults a scalar-prefetched *survival mask* rather than the raw work
counts: the static weight-only mask (``w < counts[j]`` expanded per slot)
reproduces the original walk bit-for-bit, while the runtime
activation-intersected mask (docs/DESIGN.md §12) additionally drops real
items whose activation K-slice is all zero — the two-sided skip.  Total
executed MXU passes per M-step therefore equal the *intersected* occupancy
nonzero count, not the dense ``(B-1) * K/bk * N/bn`` — the paper's "skip the
slack" realized at the front-end scheduler rather than in the kernel body.

Work items are k-major (K-tile ascending, plane within), so consecutive items
share the activation and sign blocks, and per-plane segments accumulate their
K-tiles in ascending order — the same accumulation sequence as a dense K
sweep, which keeps this kernel bit-exact against the planes oracle.

Multi-device (docs/DESIGN.md §5): the grid's N dimension partitions across a
mesh by sharding the *schedule* — ``ops.sac_matmul_pallas_sharded`` launches
this same kernel under ``jax.shard_map`` with each device holding a
contiguous slab of N-tiles plus exactly those tiles' work lists
(``ShardedKneadedWeight``), so per-device executed MXU passes equal the
shard's occupancy nonzeros and per-tile accumulation order — hence
bit-exactness — is preserved shard by shard.

``bk`` equals the kneading stride KS — the skip-granularity trade-off the
paper sweeps in Fig 11.  Larger KS: fewer, coarser skip chances but less
metadata; smaller KS: finer skips, more metadata.  With packed presence bits
(1 bit per (plane, K-tile, N-tile)) plus the int32 schedule (a count per
N-tile + 2 words per work slot, slots = N-tiles x the *max* per-tile
occupied count), metadata scales with the worst occupied N-tile rather than
the dense tile count, so small-KS schedules on sparse weights stay cheap.

VMEM budget per step (bm=bn=256, bk=512, B=8):
  A tile 256x512x4B = 512KB; one plane tile (512/32)x256x4B = 16KB;
  segment scratch 7x256x256x4B = 1.8MB; sign-multiplier cache
  512x256x4B = 512KB; out 256KB  => ~3.1MB << VMEM.
(The dense-grid kernel staged all B-1 plane tiles per step; the schedule
names one plane per item, cutting the staged plane footprint (B-1)x.)
MXU alignment: bm, bn multiples of 128; bk multiple of 256 (>= 8 sublanes of
packed words after the x32 unpack).

Decode / GEMV regime (LM serving, M = batch, often 1): the same kernel runs
with ``bm`` shrunk to the 8-row f32 sublane floor — the ops-layer
``_pad_activations`` rounds M up to a multiple of 8 and caps the M block at
that, so a one-token decode step is a single M-step grid whose A tile is
8 x bk instead of a 97%-padding 256-row slab.  The work-list walk, segment
scratch indexing, and epilogue are identical to the streamed prefill grid;
only the block shape changes, so decode output stays bit-exact against the
planes oracle (and therefore against prefill logits for the same row).
``bm`` must stay a multiple of 8 (sublane floor) — asserted below.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.schedule import KneadedSchedule

WORD = 32


def _unpack_words(words: jax.Array, bk: int) -> jax.Array:
    """[bk//32, bn] uint32 -> [bk, bn] uint32 {0,1} (little-endian per word)."""
    nw, bn = words.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (nw, WORD, bn), 1)
    bits = (words[:, None, :] >> shifts) & jnp.uint32(1)
    return bits.reshape(nw * WORD, bn)


def sac_matmul_kernel(
    mask_ref,       # scalar prefetch: [N/bn, num_work] int32 survival mask
    plane_ids_ref,  # scalar prefetch: [N/bn, num_work] int32
    ktile_ids_ref,  # scalar prefetch: [N/bn, num_work] int32
    a_ref,          # [bm, bk] activations (block of the scheduled K-tile)
    plane_ref,      # [1, bk//32, bn] uint32 — the scheduled plane, packed
    signs_ref,      # [bk//32, bn] uint32 packed sign bits
    scale_ref,      # [1, bn] f32 per-channel scales
    out_ref,        # [bm, bn] f32
    seg_ref,        # VMEM scratch: [B-1, bm, bn] f32 segment accumulators
    signf_ref,      # VMEM scratch: [bk, bn] f32 cached sign multiplier
    last_kt_ref,    # SMEM scratch: [1] int32 K-tile the sign cache holds
    *,
    bits: int,
    num_work: int,
):
    j = pl.program_id(1)
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        seg_ref[...] = jnp.zeros_like(seg_ref)
        last_kt_ref[0] = -1                # invalidate the sign cache

    @pl.when(mask_ref[j, w] != 0)          # surviving work item (else idle)
    def _mxu_pass():
        b = plane_ids_ref[j, w]            # segment register select
        kt = ktile_ids_ref[j, w]
        a = a_ref[...].astype(jnp.float32)

        # k-major order makes consecutive items share the (K-tile, N-tile)
        # sign block: unpack the {-1,+1} multiplier once per K-tile change,
        # not once per plane item (j is fixed within a tile's work walk, so
        # the K-tile id alone keys the cache).
        @pl.when(kt != last_kt_ref[0])
        def _refresh_sign_cache():
            sign_bits = _unpack_words(signs_ref[...], a.shape[1])
            # sign multiplier in {-1, +1}: 1 - 2*bit
            signf_ref[...] = 1.0 - 2.0 * sign_bits.astype(jnp.float32)
            last_kt_ref[0] = kt

        plane = _unpack_words(plane_ref[0], a.shape[1]).astype(jnp.float32)
        seg_ref[b] += jax.lax.dot_general(
            a, plane * signf_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(w == num_work - 1)
    def _rear_adder_tree():
        # Single shift-and-add over segments + single dequant scale (SAC).
        weights = (2.0 ** jnp.arange(bits - 1, dtype=jnp.float32)).reshape(
            bits - 1, 1, 1)
        acc = jnp.sum(seg_ref[...] * weights, axis=0)
        out_ref[...] = acc * scale_ref[...]


def sac_matmul_pallas_call(
    a: jax.Array,
    planes: jax.Array,
    signs: jax.Array,
    scale: jax.Array,
    schedule: KneadedSchedule,
    *,
    bits: int,
    bm: int = 256,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = True,
    mask: jax.Array | None = None,
) -> jax.Array:
    """Raw pallas_call wrapper (shapes must already be tile-aligned).

    ``mask`` is the per-slot survival mask, int32 [N/bn, num_work] — the
    *runtime* half of the two-sided skip (docs/DESIGN.md §12).  ``None``
    (the static weight-only walk) expands the schedule counts to the mask
    the pre-skip guard ``w < counts[j]`` tested, so the masked kernel is
    bit-for-bit the unmasked one.  An activation-intersected mask may
    additionally drop real items whose activation K-slice is all zero;
    surviving items keep their k-major slot positions, so per-segment f32
    accumulation order — hence bit-exactness vs the planes oracle — is
    preserved.
    """
    m, k = a.shape
    n = planes.shape[-1]
    assert bm % 8 == 0, f"bm={bm} must be a multiple of the 8-row sublane floor"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert schedule.nk == k // bk and schedule.n_tiles == n // bn, (
        schedule.nk, schedule.n_tiles, k // bk, n // bn)
    num_work = schedule.num_work
    grid = (m // bm, n // bn, num_work)
    if mask is None:
        from repro.core.activation_occupancy import weight_only_mask
        mask = weight_only_mask(schedule.counts, num_work)
    assert mask.shape == schedule.plane_ids.shape, (
        mask.shape, schedule.plane_ids.shape)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        # NB: with scalar prefetch, index maps receive the prefetch refs
        # last; they *walk the schedule* — block indices come from the work
        # lists, not from the grid coordinates.
        in_specs=[
            pl.BlockSpec((bm, bk),
                         lambda i, j, w, msk, pid, kid: (i, kid[j, w])),
            pl.BlockSpec((1, bk // WORD, bn),
                         lambda i, j, w, msk, pid, kid: (pid[j, w],
                                                         kid[j, w], j)),
            pl.BlockSpec((bk // WORD, bn),
                         lambda i, j, w, msk, pid, kid: (kid[j, w], j)),
            pl.BlockSpec((1, bn), lambda i, j, w, msk, pid, kid: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn),
                               lambda i, j, w, msk, pid, kid: (i, j)),
        scratch_shapes=[pltpu.VMEM((bits - 1, bm, bn), jnp.float32),
                        pltpu.VMEM((bk, bn), jnp.float32),
                        pltpu.SMEM((1,), jnp.int32)],
    )
    kernel = functools.partial(sac_matmul_kernel, bits=bits,
                               num_work=num_work)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(mask.astype(jnp.int32), schedule.plane_ids, schedule.ktile_ids,
      a, planes, signs, scale)
