"""Pallas TPU kernel: SAC bit-plane matmul with occupancy skipping.

Hardware mapping of the paper's PE (Fig 5) onto the TPU memory hierarchy:

  throttle buffer + pass marks  -> per-(plane, K-tile, N-tile) occupancy map,
                                   delivered via scalar prefetch (SMEM) so the
                                   skip decision is known before the tile body
  splitter array                -> in-VMEM unpack of bit-packed planes
                                   (32 weights/uint32 word) + sign application
  16x16 segment adder fabric    -> one MXU dot per *non-empty* plane tile
  segment registers S0..S15     -> VMEM scratch accumulator [B-1, bm, bn] f32
  rear adder tree (shift once)  -> epilogue ``sum_b 2^b * S_b`` executed once
                                   per output tile at the last K step
  per-channel scale             -> applied once in the same epilogue (SAC's
                                   "no intermediate pair-wise partial sums")

Tiling: grid (M/bm, N/bn, K/bk) with K innermost (revisiting=output-stationary).
``bk`` equals the kneading stride KS — the skip granularity trade-off the
paper sweeps in Fig 11 (larger KS: fewer, coarser skip opportunities but less
metadata; smaller KS: more skips, more SMEM metadata).

VMEM budget per step (bm=bn=256, bk=512, B=8):
  A tile 256x512x4B = 512KB; plane tiles 7x(512/32)x256x4B = 114KB;
  segment scratch 7x256x256x4B = 1.8MB; out 256KB  => ~2.7MB << VMEM.
MXU alignment: bm, bn multiples of 128; bk multiple of 256 (>= 8 sublanes of
packed words after the x32 unpack).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

WORD = 32


def _unpack_words(words: jax.Array, bk: int) -> jax.Array:
    """[bk//32, bn] uint32 -> [bk, bn] uint32 {0,1} (little-endian per word)."""
    nw, bn = words.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (nw, WORD, bn), 1)
    bits = (words[:, None, :] >> shifts) & jnp.uint32(1)
    return bits.reshape(nw * WORD, bn)


def sac_matmul_kernel(
    occ_ref,        # scalar prefetch: [B-1, K/bk, N/bn] int32
    a_ref,          # [bm, bk] activations
    planes_ref,     # [B-1, bk//32, bn] uint32 packed magnitude planes
    signs_ref,      # [bk//32, bn] uint32 packed sign bits
    scale_ref,      # [1, bn] f32 per-channel scales
    out_ref,        # [bm, bn] f32
    seg_ref,        # VMEM scratch: [B-1, bm, bn] f32 segment accumulators
    *,
    bits: int,
    nk: int,
):
    k_idx = pl.program_id(2)
    n_idx = pl.program_id(1)

    @pl.when(k_idx == 0)
    def _init():
        seg_ref[...] = jnp.zeros_like(seg_ref)

    a = a_ref[...].astype(jnp.float32)
    sign_bits = _unpack_words(signs_ref[...], a.shape[1])
    # sign multiplier in {-1, +1}: 1 - 2*bit
    signf = 1.0 - 2.0 * sign_bits.astype(jnp.float32)

    for b in range(bits - 1):  # static unroll over planes ("splitter array")
        @pl.when(occ_ref[b, k_idx, n_idx] > 0)   # pass-mark skip
        def _accumulate(b=b):
            plane = _unpack_words(planes_ref[b], a.shape[1]).astype(jnp.float32)
            seg_ref[b] += jax.lax.dot_general(
                a, plane * signf,
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

    @pl.when(k_idx == nk - 1)
    def _rear_adder_tree():
        # Single shift-and-add over segments + single dequant scale (SAC).
        weights = (2.0 ** jnp.arange(bits - 1, dtype=jnp.float32)).reshape(
            bits - 1, 1, 1)
        acc = jnp.sum(seg_ref[...] * weights, axis=0)
        out_ref[...] = acc * scale_ref[...]


def sac_matmul_pallas_call(
    a: jax.Array,
    planes: jax.Array,
    signs: jax.Array,
    scale: jax.Array,
    occupancy: jax.Array,
    *,
    bits: int,
    bm: int = 256,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Raw pallas_call wrapper (shapes must already be tile-aligned)."""
    m, k = a.shape
    n = planes.shape[-1]
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert occupancy.shape == (bits - 1, k // bk, n // bn), occupancy.shape
    nk = k // bk
    grid = (m // bm, n // bn, nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        # NB: with scalar prefetch, index maps receive the prefetch ref last.
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk, occ: (i, kk)),
            pl.BlockSpec((bits - 1, bk // WORD, bn),
                         lambda i, j, kk, occ: (0, kk, j)),
            pl.BlockSpec((bk // WORD, bn), lambda i, j, kk, occ: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk, occ: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, occ: (i, j)),
        scratch_shapes=[pltpu.VMEM((bits - 1, bm, bn), jnp.float32)],
    )
    kernel = functools.partial(sac_matmul_kernel, bits=bits, nk=nk)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(occupancy, a, planes, signs, scale)
