"""Pallas TPU kernel: kneaded integer GEMM (int8 / nibble-packed int4).

The beyond-paper production variant of SAC for serving: instead of one MXU
pass per bit plane, the integer codes are kept *packed in HBM* (1 B or 0.5 B
per weight vs 2 B bf16 — a 2x/4x cut of the decode memory-roofline term),
unpacked in VMEM, and multiplied in a single MXU pass per tile.  The SAC
principle survives as the *deferred epilogue*: no intermediate pair-wise
dequantized products ever exist; the per-channel scale ("rear adder tree +
scale") is applied exactly once per output tile.

Grid (M/bm, N/bn, K/bk), K innermost, f32 VMEM scratch accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, q_ref, scale_ref, out_ref, acc_ref, *, nk: int, packed4: bool):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    q = q_ref[...]
    if packed4:
        low = jnp.right_shift(jnp.left_shift(q, 4), 4)   # sign-extend
        high = jnp.right_shift(q, 4)
        kw, bn = q.shape
        q = jnp.stack([low, high], axis=1).reshape(kw * 2, bn)
    w = q.astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        a, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_idx == nk - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...] * scale_ref[...]     # scale applied ONCE


def kneaded_gemm_pallas_call(
    a: jax.Array,
    q: jax.Array,
    scale: jax.Array,
    *,
    packed4: bool = False,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """[M, K] @ int codes [K, N] (or [K/2, N] packed int4) -> [M, N] f32."""
    m, k = a.shape
    kq, n = q.shape
    assert kq * (2 if packed4 else 1) == k, (kq, k, packed4)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    nk = k // bk
    bkq = bk // 2 if packed4 else bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, packed4=packed4),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bkq, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, q, scale)
