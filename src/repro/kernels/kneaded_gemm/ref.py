"""Pure-jnp oracle for the kneaded integer GEMM kernel (int8 / packed int4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def unpack_int4(packed: jax.Array) -> jax.Array:
    """[K/2, N] int8 (two nibbles along K, little-nibble first) -> [K, N] int8."""
    low = jnp.left_shift(packed, 4)
    low = jnp.right_shift(low, 4)                       # sign-extended low nibble
    high = jnp.right_shift(packed, 4)                   # arithmetic shift: high
    k2, n = packed.shape
    out = jnp.stack([low, high], axis=1)                # [K/2, 2, N]
    return out.reshape(k2 * 2, n)


def pack_int4(q: jax.Array) -> jax.Array:
    """[K, N] int8 in [-8, 7] -> [K/2, N] int8 nibble-packed."""
    k, n = q.shape
    assert k % 2 == 0
    q = q.reshape(k // 2, 2, n)
    low = q[:, 0].astype(jnp.uint8) & 0xF
    high = (q[:, 1].astype(jnp.uint8) & 0xF) << 4
    return (low | high).astype(jnp.int8)


def kneaded_gemm_ref(a: jax.Array, q: jax.Array, scale: jax.Array,
                     packed4: bool = False) -> jax.Array:
    """f32 reference: A @ (q * scale) with epilogue scaling."""
    if packed4:
        q = unpack_int4(q)
    out = jnp.dot(a.astype(jnp.float32), q.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return out * scale
