"""Jitted public wrapper for the kneaded integer GEMM kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kneaded_gemm.kernel import kneaded_gemm_pallas_call
from repro.kernels.kneaded_gemm.ref import pack_int4


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("packed4", "bm", "bn", "bk", "interpret"))
def _run(a, q, scale, *, packed4, bm, bn, bk, interpret):
    return kneaded_gemm_pallas_call(
        a, q, scale, packed4=packed4, bm=bm, bn=bn, bk=bk, interpret=interpret)


def kneaded_gemm(
    a: jax.Array,
    q: jax.Array,
    scale: jax.Array,
    *,
    packed4: bool = False,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Integer-kneaded GEMM with deferred scale; pads M to the tile size."""
    if interpret is None:
        interpret = not _on_tpu()
    m, k = a.shape
    n = q.shape[-1]
    bm_eff = min(bm, max(8, m))
    bn_eff = min(bn, n)
    bk_eff = min(bk, k)
    pad = (-m) % bm_eff
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
    out = _run(a, q, scale.reshape(1, -1).astype(jnp.float32),
               packed4=packed4, bm=bm_eff, bn=bn_eff, bk=bk_eff,
               interpret=interpret)
    return out[:m] if pad else out


def pack_weights_int4(q8: jax.Array) -> jax.Array:
    """Nibble-pack int8 codes in [-8, 7] (bits=4 quantization) along K."""
    return pack_int4(q8)
