"""Logical-axis sharding constraints, mesh-agnostic for model code.

Model code annotates activations with *logical* axes ("batch", "model",
"seq", None).  The launcher installs a mapping from logical axes to physical
mesh axes (e.g. batch -> ("pod", "data")); outside any mapping the helpers are
no-ops, so the same model code runs on one CPU device and on a 512-chip mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

Logical = Union[str, None, Tuple[str, ...]]

DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "model": ("model",),
    # Experts prefer the dedicated "expert" axis of the 2-D serving mesh
    # (launch.mesh.make_serving_mesh, docs/DESIGN.md §13); on meshes
    # without one (training, 1-D serving) the axis filter below falls back
    # to the historical EP-over-"model" placement.
    "expert": ("expert", "model"),
    "seq": ("model",),  # sequence-parallel residuals (cfg.sequence_parallel)
}


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def current_rules() -> Dict[str, Tuple[str, ...]]:
    return getattr(_state, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    """Install a mesh + logical->physical mapping for `constrain` calls."""
    prev = (getattr(_state, "mesh", None), getattr(_state, "rules", None))
    _state.mesh = mesh
    base = dict(DEFAULT_RULES)
    # Drop physical axes the mesh doesn't actually have (single-pod mesh).
    mesh_axes = set(mesh.axis_names)
    base = {k: tuple(a for a in v if a in mesh_axes) for k, v in base.items()}
    if rules:
        base.update(rules)
    _state.rules = base
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def resolve(spec: Sequence[Logical]) -> P:
    """Map logical axis names to a PartitionSpec under the current rules."""
    rules = current_rules()
    out = []
    for s in spec:
        if s is None:
            out.append(None)
        elif isinstance(s, tuple):
            axes = sum((rules.get(a, (a,)) for a in s), ())
            out.append(axes if axes else None)
        else:
            axes = rules.get(s, (s,))
            out.append(axes if axes else None)
    return P(*out)


def constrain(x: jax.Array, *spec: Logical) -> jax.Array:
    """with_sharding_constraint under the installed mesh; no-op otherwise."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve(spec)))
