"""Post-SPMD HLO analysis: FLOPs / bytes / collective traffic per device.

XLA's ``compiled.cost_analysis()`` visits while-loop bodies ONCE (verified
empirically on this backend) — useless for scan-over-layers models where the
body runs L times.  This module parses ``compiled.as_text()`` directly:

  * builds a symbol table name -> shape (instruction results + block params;
    the CPU HLO printer omits operand types on op lines),
  * per computation block, accumulates
      - dot FLOPs        (2 * prod(out_shape) * prod(contracted dims)),
      - dot bytes        (lhs + rhs + out bytes — the HBM-traffic proxy for
                          matmul-dominated models; elementwise traffic is not
                          counted, recorded as a known approximation),
      - collective bytes (result bytes of all-reduce / all-gather /
                          reduce-scatter / all-to-all / collective-permute),
  * resolves the call graph: while bodies are multiplied by the trip count
    from ``backend_config known_trip_count`` (fallback: largest integer
    constant in the loop condition), conditionals take the max over branches
    (upper bound, noted), calls/fusions count once.

All shapes in the partitioned module are per-device shapes, so every number
returned is *per device*; multiply by chip count for the global value.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?)((?:\w+\[[\d,]*\][^\s]*)?)")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*(\w+\[[\d,]*\])")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _dims(dim_str: str) -> Tuple[int, ...]:
    return tuple(int(d) for d in dim_str.split(",") if d)


def _nelems(dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _shape_bytes(dtype: str, dims: Tuple[int, ...]) -> int:
    return _nelems(dims) * _DTYPE_BYTES.get(dtype, 0)


@dataclasses.dataclass
class BlockStats:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    calls: List[Tuple[str, Tuple[str, ...]]] = dataclasses.field(
        default_factory=list)
    max_int_const: int = 1
    unresolved_dots: int = 0


_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CALL_RE = re.compile(
    r"(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=%?([\w.\-]+)")
_COND_BRANCH_RE = re.compile(
    r"conditional\(.*?\).*?branch_computations=\{([^}]*)\}")
_COND_TF_RE = re.compile(
    r"conditional\(.*?\).*?true_computation=%?([\w.\-]+).*?"
    r"false_computation=%?([\w.\-]+)")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _parse(hlo: str):
    """Returns (blocks, entry, symbols) where symbols maps %name -> list of
    (dtype, dims) (tuples for tuple-typed results)."""
    blocks: Dict[str, BlockStats] = {}
    symbols: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    lines_by_block: Dict[str, List[str]] = {}
    for raw in hlo.splitlines():
        s = raw.strip()
        if s.endswith("{") and "->" in s and not s.startswith("//"):
            toks = s.split()
            name = toks[1] if toks[0] == "ENTRY" and len(toks) > 1 else toks[0]
            cur = name.lstrip("%").rstrip("(")
            blocks[cur] = BlockStats()
            lines_by_block[cur] = []
            if toks[0] == "ENTRY":
                entry = cur
            # header params: "(name: f32[..], name2: (f32[..], ...))"
            for pname, ptype in _PARAM_RE.findall(s):
                m = _SHAPE_RE.findall(ptype)
                if m:
                    symbols[pname] = [(dt, _dims(dm)) for dt, dm in m]
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        lines_by_block[cur].append(s)
        m = _DEF_RE.match(s)
        if m:
            name = m.group(1)
            # result type(s): everything between '=' and the op name
            rhs = s.split("=", 1)[1]
            # cut at the op call to avoid operand/attribute shapes
            opm = re.search(r"[\w\-]+\(", rhs)
            type_part = rhs[:opm.start()] if opm else rhs
            shapes = _SHAPE_RE.findall(type_part)
            if shapes:
                symbols[name] = [(dt, _dims(dm)) for dt, dm in shapes]
    return blocks, entry, symbols, lines_by_block


def _operand_names(s: str) -> List[str]:
    opm = re.search(r"[\w\-]+\((.*)\)(?:,|$| )", s)
    seg = opm.group(1) if opm else ""
    return [t.strip().lstrip("%") for t in seg.split(",") if t.strip()]


def _fill_block_stats(blocks, symbols, lines_by_block):
    for bname, lines in lines_by_block.items():
        b = blocks[bname]
        for s in lines:
            for c in re.findall(r"constant\((\d+)\)", s):
                b.max_int_const = max(b.max_int_const, int(c))
            if " while(" in s:
                m2 = _WHILE_RE.search(s)
                if m2:
                    m3 = re.search(r"known_trip_count[^0-9]*(\d+)", s)
                    if m3:
                        b.calls.append(("while_known",
                                        (m2.group(1), m2.group(2),
                                         m3.group(1))))
                    else:
                        b.calls.append(("while",
                                        (m2.group(1), m2.group(2))))
                continue
            if " conditional(" in s:
                m2 = _COND_BRANCH_RE.search(s)
                if m2:
                    names = tuple(x.strip().lstrip("%")
                                  for x in m2.group(1).split(","))
                    b.calls.append(("cond", names))
                else:
                    m2 = _COND_TF_RE.search(s)
                    if m2:
                        b.calls.append(("cond", (m2.group(1), m2.group(2))))
                continue
            if (" call(" in s or " fusion(" in s):
                m2 = _CALL_RE.search(s)
                if m2:
                    b.calls.append(("call", (m2.group(1),)))
                # fall through: fusion lines never contain dots themselves
            if " dot(" in s:
                mdef = _DEF_RE.match(s)
                out_shapes = symbols.get(mdef.group(1), []) if mdef else []
                ops = _operand_names(s)
                contract = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", s)
                lhs_shape = symbols.get(ops[0], [(None, ())])[0][1] \
                    if ops else ()
                rhs_shape = symbols.get(ops[1], [(None, ())])[0][1] \
                    if len(ops) > 1 else ()
                if out_shapes and contract and lhs_shape:
                    cdims = [int(x) for x in contract.group(1).split(",") if x]
                    k = 1
                    for ci in cdims:
                        if ci < len(lhs_shape):
                            k *= lhs_shape[ci]
                    out_dt, out_dims = out_shapes[0]
                    b.dot_flops += 2.0 * _nelems(out_dims) * k
                    b.dot_bytes += (_shape_bytes(out_dt, out_dims)
                                    + _shape_bytes("f32", lhs_shape)
                                    + _shape_bytes("f32", rhs_shape))
                else:
                    b.unresolved_dots += 1
                continue
            if " convolution(" in s:
                mdef = _DEF_RE.match(s)
                out_shapes = symbols.get(mdef.group(1), []) if mdef else []
                ops = _operand_names(s)
                kern = symbols.get(ops[1], [(None, ())])[0][1] \
                    if len(ops) > 1 else ()
                if out_shapes and kern:
                    out_dt, out_dims = out_shapes[0]
                    # flops ~= 2 * out * (kernel elems per output channel)
                    b.dot_flops += 2.0 * _nelems(out_dims) * max(
                        _nelems(kern) // max(kern[-1], 1), 1)
                    b.dot_bytes += _shape_bytes(out_dt, out_dims)
                continue
            for cname in _COLLECTIVES:
                if f" {cname}(" in s or f" {cname}-start(" in s:
                    mdef = _DEF_RE.match(s)
                    shapes = symbols.get(mdef.group(1), []) if mdef else []
                    byts = sum(_shape_bytes(dt, dm) for dt, dm in shapes)
                    # CPU-backend float-normalization artifacts (TPU keeps
                    # bf16): (a) bf16 reductions promoted to f32 (reducer
                    # "*_promoted"); (b) bf16 DOTS promoted to f32, so the
                    # FSDP all-gathers feeding them show f32.  Count both
                    # at their true (model-level bf16) width.
                    promoted_reduce = ("promoted" in s
                                       and all(dt == "f32"
                                               for dt, _ in shapes))
                    # every weight/activation gather in this framework is
                    # bf16 at the model level (params cast once per step);
                    # f32 gathers exist only because CPU float-normalization
                    # promoted the consuming bf16 op.
                    promoted_dot_feed = (cname == "all-gather"
                                         and all(dt == "f32"
                                                 for dt, _ in shapes))
                    if promoted_reduce or promoted_dot_feed:
                        byts //= 2
                    b.coll_bytes[cname] = b.coll_bytes.get(cname, 0.0) + byts
                    break


def _resolve(blocks: Dict[str, BlockStats], name: str, memo):
    if name in memo:
        return memo[name]
    if name not in blocks:
        return (0.0, 0.0, {})
    memo[name] = (0.0, 0.0, {})          # cycle guard
    b = blocks[name]
    flops, byts = b.dot_flops, b.dot_bytes
    coll = dict(b.coll_bytes)

    def add(dst, src, mult):
        for k, v in src.items():
            dst[k] = dst.get(k, 0.0) + v * mult

    for kind, targets in b.calls:
        if kind in ("while", "while_known"):
            cond, body = targets[0], targets[1]
            trip = (int(targets[2]) if kind == "while_known"
                    else (blocks[cond].max_int_const if cond in blocks else 1))
            f2, b2, c2 = _resolve(blocks, body, memo)
            fc, bc, cc = _resolve(blocks, cond, memo)
            flops += trip * (f2 + fc)
            byts += trip * (b2 + bc)
            add(coll, c2, trip)
            add(coll, cc, trip)
        elif kind == "cond":
            best = (0.0, 0.0, {})
            for t in targets:
                r = _resolve(blocks, t, memo)
                if r[0] + r[1] > best[0] + best[1]:
                    best = r
            flops += best[0]
            byts += best[1]
            add(coll, best[2], 1.0)
        else:
            for t in targets:
                f2, b2, c2 = _resolve(blocks, t, memo)
                flops += f2
                byts += b2
                add(coll, c2, 1.0)
    memo[name] = (flops, byts, coll)
    return memo[name]


@dataclasses.dataclass
class HloStats:
    """Per-device totals for one compiled executable."""

    dot_flops: float
    dot_bytes: float
    collective_bytes: Dict[str, float]
    unresolved_dots: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze_hlo(hlo_text: str) -> HloStats:
    blocks, entry, symbols, lines_by_block = _parse(hlo_text)
    _fill_block_stats(blocks, symbols, lines_by_block)
    if entry is None:
        entry = max(blocks, key=lambda k: blocks[k].dot_flops + 1)
    flops, byts, coll = _resolve(blocks, entry, {})
    return HloStats(dot_flops=flops, dot_bytes=byts, collective_bytes=coll,
                    unresolved_dots=sum(b.unresolved_dots
                                        for b in blocks.values()))


def collective_provenance(hlo_text: str, top: int = 12):
    """§Perf diagnostic: the top collective contributors, with the effective
    loop multiplier, payload dtype/shape, and the jax op_name provenance.

    Returns a list of dicts sorted by (multiplier * bytes) descending.
    """
    blocks, entry, symbols, lines_by_block = _parse(hlo_text)
    _fill_block_stats(blocks, symbols, lines_by_block)
    # block -> effective multiplier via BFS from entry
    mult: Dict[str, float] = {entry: 1.0}
    order = [entry]
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        if name not in blocks:
            continue
        m = mult[name]
        for kind, targets in blocks[name].calls:
            if kind in ("while", "while_known"):
                cond, body = targets[0], targets[1]
                trip = (int(targets[2]) if kind == "while_known" else
                        (blocks[cond].max_int_const if cond in blocks else 1))
                kids = [(cond, m * trip), (body, m * trip)]
            else:
                kids = [(t, m) for t in targets]
            for t, tm in kids:
                if mult.get(t, 0.0) < tm:
                    mult[t] = tm
                    order.append(t)
    out = []
    for bname, lines in lines_by_block.items():
        m = mult.get(bname, 0.0)
        if m <= 0:
            continue
        for s in lines:
            for cname in _COLLECTIVES:
                if f" {cname}(" in s or f" {cname}-start(" in s:
                    mdef = _DEF_RE.match(s)
                    shapes = symbols.get(mdef.group(1), []) if mdef else []
                    byts = sum(_shape_bytes(dt, dm) for dt, dm in shapes)
                    mm = re.search(r'op_name="([^"]*)"', s)
                    out.append({
                        "kind": cname,
                        "bytes": byts,
                        "mult": m,
                        "total": byts * m,
                        "type": " ".join(f"{dt}{list(dm)}"
                                         for dt, dm in shapes[:2]),
                        "op_name": (mm.group(1)[-120:] if mm else "?"),
                    })
                    break
    out.sort(key=lambda r: -r["total"])
    return out[:top]


def roofline_terms(stats: HloStats, *, chips: int,
                   peak_flops: float, hbm_bw: float,
                   ici_bw: float,
                   hbm_bytes: Optional[float] = None) -> Dict[str, float]:
    """The three §Roofline terms, in seconds (per step, per device).

    ``hbm_bytes``: per-device working set (argument+output+temp from
    memory_analysis) — every byte is touched at least once per step, so
    this is the defensible lower-bound HBM-traffic proxy (dot_bytes, the
    fusion-blind upper bound, is reported as a diagnostic only).
    """
    mem = hbm_bytes if hbm_bytes is not None else stats.dot_bytes
    return {
        "compute_s": stats.dot_flops / peak_flops,
        "memory_s": mem / hbm_bw,
        "collective_s": stats.total_collective_bytes / ici_bw,
    }
