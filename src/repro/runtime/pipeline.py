"""GPipe-style pipeline parallelism over the "pod" axis (shard_map).

At two pods the cross-pod (DCN-class) link is the weakest; pipelining layers
across pods converts per-layer FSDP gathers over that link into one
activation hand-off per microbatch per stage boundary — the canonical
PP trade (bandwidth per step: activations*num_microbatches vs params*2).

Implementation: the classic collective_permute schedule.  Each pod owns
``num_layers / num_stages`` layers (stacked param leading dim is split).
Microbatches stream through: at tick t, stage s runs microbatch (t - s) if
0 <= t - s < M, then the activations rotate one stage forward.  Bubble
fraction = (S-1)/(M+S-1).

This is an optional execution mode (``--pipeline`` in launch.train and the
pp dry-run in EXPERIMENTS.md §Dry-run): DP/TP (FSDP+TP) remains the default.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def pipeline_apply(
    layer_fn: Callable[[PyTree, jax.Array], jax.Array],
    stacked_params: PyTree,
    x: jax.Array,                    # [M, mb, S, D] microbatched activations
    mesh: Mesh,
    stage_axis: str = "pod",
) -> jax.Array:
    """Run ``layer_fn`` over stacked layers, pipelined across ``stage_axis``.

    stacked_params leaves: [L, ...] with L % num_stages == 0.
    x: [M, mb, ...] microbatches (M >= num_stages for reasonable bubbles).
    Returns activations in the same [M, mb, ...] layout.
    """
    num_stages = mesh.shape[stage_axis]
    m = x.shape[0]

    def stage_fn(params_local, x_local):
        # params_local: [L/S, ...]; x_local: full [M, mb, ...] (replicated on
        # the stage axis — each stage computes its slice of the schedule)
        stage = jax.lax.axis_index(stage_axis)

        def run_stage(xmb):
            def body(h, p_l):
                return layer_fn(p_l, h), None
            h, _ = jax.lax.scan(body, xmb, params_local)
            return h

        def tick(carry, t):
            buf = carry                       # [M, mb, ...] rolling buffer
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < m)
            idx = jnp.clip(mb_idx, 0, m - 1)
            xmb = jax.lax.dynamic_index_in_dim(buf, idx, 0, keepdims=False)
            ymb = jax.lax.cond(active, run_stage, lambda z: z, xmb)
            buf = jax.lax.dynamic_update_index_in_dim(
                buf, ymb, idx, 0)
            # hand the buffer one stage forward; the last stage feeds results
            # back to stage 0's buffer slot (ring), which is correct because
            # each microbatch is only re-read after all stages touched it.
            buf = jax.lax.ppermute(
                buf, stage_axis,
                [(i, (i + 1) % num_stages) for i in range(num_stages)])
            return buf, None

        total_ticks = m + num_stages - 1
        buf, _ = jax.lax.scan(tick, x_local, jnp.arange(total_ticks))
        # Each physical ring buffer carries exactly the microbatches whose
        # phase matches its starting stage (slot m rides the buffer that
        # meets stage s at tick m+s).  The stage holding buffer j at the end
        # owns the finished slots with m % S == (total_ticks - stage) % S;
        # mask the rest and combine across stages with one psum.
        own = (jnp.arange(m) % num_stages) == ((total_ticks - stage)
                                               % num_stages)
        own = own.reshape((m,) + (1,) * (buf.ndim - 1))
        return jax.lax.psum(jnp.where(own, buf, 0), stage_axis)

    in_specs = (jax.tree.map(lambda _: P(stage_axis), stacked_params),
                P())
    return shard_map(stage_fn, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_rep=False)(stacked_params, x)
