"""Fault tolerance + straggler mitigation for the training loop.

At 1000+ nodes, the assumptions are: (a) any step can raise (device loss,
preemption, network partition) and the job must resume from the last durable
checkpoint; (b) step-time outliers (stragglers) must be detected and
surfaced, because a single slow host gates every synchronous collective.

Components:
  * ``RestartPolicy``      — bounded retries with exponential backoff.
  * ``StepTimer``          — EWMA + robust z-score straggler watermark; at
                             real scale the per-host step times come from the
                             coordination service, here from the local clock.
  * ``FailureInjector``    — deterministic fault injection for tests/examples
                             (raises ``InjectedFailure`` at chosen steps).
  * ``run_resilient_loop`` — the restart loop used by train.trainer.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Iterable, List, Optional


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class RestartPolicy:
    """Bounded retries with capped, optionally jittered exponential backoff.

    ``backoff_cap_s`` bounds the exponential growth (a long fault streak
    must not sleep for hours); ``jitter_frac`` adds up to that fraction of
    uniform random extra sleep so restarting replicas de-synchronize
    (thundering-herd avoidance) — 0.0 keeps sleeps deterministic for tests.
    """

    max_restarts: int = 3
    backoff_s: float = 0.1
    backoff_mult: float = 2.0
    backoff_cap_s: float = 30.0
    jitter_frac: float = 0.0

    def sleep_s(self, backoff: float, rng: Optional[random.Random] = None
                ) -> float:
        """Actual sleep for a nominal backoff: capped, plus jitter."""
        base = min(backoff, self.backoff_cap_s)
        if self.jitter_frac <= 0.0:
            return base
        r = rng.random() if rng is not None else random.random()
        return base * (1.0 + self.jitter_frac * r)

    def next_backoff(self, backoff: float) -> float:
        return min(backoff * self.backoff_mult, self.backoff_cap_s)


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: Iterable[int] = ()
    fail_once: bool = True

    def __post_init__(self):
        self._pending = set(self.fail_at_steps)

    def maybe_fail(self, step: int):
        if step in self._pending:
            if self.fail_once:
                self._pending.discard(step)
            raise InjectedFailure(f"injected failure at step {step}")


class StepTimer:
    """Tracks step latency; flags stragglers at mean + k*MAD."""

    def __init__(self, k: float = 5.0, warmup: int = 3):
        self.k = k
        self.warmup = warmup
        self.times: List[float] = []
        self._t0: Optional[float] = None
        self.straggler_steps: List[int] = []

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        if len(self.times) >= self.warmup:
            med = sorted(self.times)[len(self.times) // 2]
            mad = sorted(abs(t - med) for t in self.times)[len(self.times) // 2]
            if dt > med + self.k * max(mad, 1e-4):
                self.straggler_steps.append(step)
        self.times.append(dt)
        return dt


def run_resilient_loop(
    *,
    start_step: int,
    num_steps: int,
    step_fn: Callable[[int], None],
    restore_fn: Callable[[], int],
    policy: Optional[RestartPolicy] = None,
) -> int:
    """Run ``step_fn(step)`` for steps [start, num_steps); on exception,
    call ``restore_fn() -> resume_step`` and continue.  Returns restarts.

    Backoff resets to ``policy.backoff_s`` after any successful step — only
    *consecutive* faults escalate the sleep — and is capped at
    ``policy.backoff_cap_s`` with optional jitter (see
    :meth:`RestartPolicy.sleep_s`).  The default policy is constructed per
    call: a dataclass instance in the signature would be shared across every
    caller of the loop (the classic mutable-default trap).
    """
    policy = RestartPolicy() if policy is None else policy
    restarts = 0
    backoff = policy.backoff_s
    step = start_step
    while step < num_steps:
        try:
            step_fn(step)
            step += 1
            backoff = policy.backoff_s         # clean step: de-escalate
        except Exception:  # noqa: BLE001 — any fault triggers the restart path
            restarts += 1
            if restarts > policy.max_restarts:
                raise
            time.sleep(policy.sleep_s(backoff))
            backoff = policy.next_backoff(backoff)
            step = restore_fn()
    return restarts
