"""Parameter / input partition rules (FSDP + TP + EP).

The rules map parameter-tree paths to PartitionSpecs over the production mesh
axes ("pod", "data", "model").  Strategy (MaxText-style):

  * 2-D projection weights:  P(fsdp, "model")  — input dim sharded over the
    data axes (FSDP, gathered on use, which the per-layer scan makes a
    per-layer all-gather), output dim tensor-parallel over "model".
  * "reducing" projections (wo / out_proj / down — whose *input* is the
    TP-sharded dim): P("model", fsdp), so the subsequent contraction
    generates the canonical TP all-reduce.
  * MoE experts: expert axis over "model" (EP), input dim over fsdp.
  * embed [V, D]: P("model", fsdp);  unembed [D, V]: P(fsdp, "model").
  * 1-D scales/biases and tiny tensors: replicated.

Any axis that does not divide its dim evenly falls back to None (correct,
just less sharded) — this keeps every assigned arch lowerable without
per-arch special cases.  Stacked-layer leading dims (scan) are never sharded.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Optional, Tuple

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# ------------------------------------------------- kneaded serving mesh context
#
# The LM serving stack dispatches sharded kneaded matmuls from deep inside
# the model's layer scans (models/blocks.py -> layers.matmul_any ->
# core.sac.sac_matmul), where no mesh argument can be threaded without
# touching every block signature.  The engine installs the mesh here around
# its (jitted) calls — read at TRACE time by the sharded dispatch, exactly
# like runtime.pspec's logical-axis rules.  No mesh installed means the
# serial single-device shard walk (the parity oracle).

_serving = threading.local()


def current_serving_mesh() -> Tuple[Optional[Mesh], str]:
    """(mesh, axis) the sharded kneaded dispatch should launch under;
    (None, axis) = execute shards serially on the local device."""
    return (getattr(_serving, "mesh", None),
            getattr(_serving, "axis", "model"))


@contextlib.contextmanager
def serving_mesh(mesh: Optional[Mesh], axis: str = "model"):
    """Install the mesh sharded KneadedWeight matmuls shard_map over."""
    prev = (getattr(_serving, "mesh", None),
            getattr(_serving, "axis", "model"))
    _serving.mesh, _serving.axis = mesh, axis
    try:
        yield
    finally:
        _serving.mesh, _serving.axis = prev

# parameter name -> (spec for trailing dims), matched on the *last* path key
# or a distinctive substring of the joined path.  fsdp == ("pod","data")∩mesh.
_REVERSED = ("wo", "out_proj", "down", "w_out")          # P(model, fsdp)
_REPLICATED = ("scale", "bias", "a_log", "dt_bias", "d_skip", "f_bias",
               "cross_gate", "qnorm", "knorm", "b")
_POS = ("pos_embed", "dec_pos_embed")


def _axes_of(mesh: Mesh, names: Tuple[str, ...]) -> Tuple[str, ...]:
    return tuple(a for a in names if a in mesh.axis_names)


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    if not axes:
        return False
    n = int(np.prod([mesh.shape[a] for a in (
        axes if isinstance(axes, tuple) else (axes,))]))
    return dim % n == 0


def _maybe(dim: int, mesh: Mesh, axes):
    return axes if _fits(dim, mesh, axes) else None


def dp_batch_axes(mesh: Mesh, global_batch: int) -> Tuple[str, ...]:
    """Batch axes for the "dp" profile: the largest axis combination that
    divides the batch, preferring to keep "pod" as plain DP on multi-pod
    (pods must not duplicate work)."""
    # Any axis NOT in the batch replicates compute: leaving "pod" out
    # duplicates 2x, leaving "model" out 16x (measured: multi-pod dp train
    # cells dropped to useful=0.05 with batch over (pod,data) — §Perf it.8),
    # so prefer dropping "pod" first.
    for cand in (("pod", "data", "model"), ("data", "model"),
                 ("pod", "data"), ("data",)):
        axes = _axes_of(mesh, cand)
        if axes and _fits(global_batch, mesh, axes):
            return axes
    return ()


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
               mode: str = "tp") -> P:
    """PartitionSpec for one parameter leaf given its tree path.

    mode="tp": FSDP over (pod, data) + tensor parallel over "model".
    mode="dp": ZeRO-3 — params fully sharded over EVERY mesh axis on their
    widest dim, no TP dim; activations carry no model-axis collectives.
    """
    fsdp = _axes_of(mesh, ("pod", "data"))
    model = _axes_of(mesh, ("model",))
    model = model[0] if model else None
    last = path.rsplit("/", 1)[-1]

    if last in _REPLICATED or not shape or int(np.prod(shape)) < 65536:
        return P()
    if mode == "serve":
        # Serving layout: shard ONLY non-contraction (output) dims, over as
        # many axes as divide.  Weights arrive pre-sharded where the matmul
        # needs them: no per-layer weight all-gathers (the train-layout FSDP
        # contraction dims cost a full weight gather per layer per token
        # step — measured 107 GiB/device on nemotron decode_32k); the only
        # collectives left are [B,1,D]-sized activation reduce-scatters.
        # MoE expert weights keep the EP layout (shard_map contract).
        all_axes = _axes_of(mesh, ("pod", "data", "model"))
        dm = _axes_of(mesh, ("data", "model"))
        if "moe/wi" in path or "moe/wo" in path:
            lead = len(shape) - 3
            return P(*([None] * lead), _maybe(shape[-3], mesh, model),
                     None, None)
        if last in _POS:
            return P(*([None] * (len(shape) - 2)),
                     _maybe(shape[-2], mesh, all_axes), None)
        if last == "embed":
            return P(_maybe(shape[0], mesh, all_axes), None)
        if len(shape) >= 2:
            lead = len(shape) - 2
            d_out = shape[-1]
            for axes in (all_axes, dm, fsdp, (model,) if model else ()):
                if axes and _fits(d_out, mesh, axes):
                    return P(*([None] * lead), None, axes)
            return P()
        return P(_maybe(shape[0], mesh, all_axes))
    if mode == "dp":
        all_axes = _axes_of(mesh, ("pod", "data", "model"))
        if last in _POS:
            return P(*([None] * (len(shape) - 2)),
                     _maybe(shape[-2], mesh, all_axes), None)
        # shard the widest trailing dim over everything; fall back smaller
        lead = len(shape) - 2 if len(shape) >= 2 else 0
        d0 = shape[lead] if len(shape) >= 2 else shape[0]
        for axes in (all_axes, _axes_of(mesh, ("data", "model")), fsdp):
            if axes and _fits(d0, mesh, axes):
                if len(shape) >= 2:
                    return P(*([None] * lead), axes, None)
                return P(axes)
        return P()
    if last in _POS:
        # learned positional tables: shard rows over model when divisible
        return P(*([None] * (len(shape) - 2)),
                 _maybe(shape[-2], mesh, model), None)

    # how many leading stack dims (scan axes) to skip: match trailing dims
    if last == "embed":
        return P(_maybe(shape[0], mesh, model), _maybe(shape[1], mesh, fsdp))
    if last == "unembed":
        return P(_maybe(shape[0], mesh, fsdp), _maybe(shape[1], mesh, model))

    if "moe/wi" in path or "moe/wo" in path:
        # [L, E, D, F'] / [L, E, F, D]: EP over model, fsdp on the wide dim
        lead = len(shape) - 3
        e, d0, d1 = shape[-3:]
        spec = [None] * lead + [
            _maybe(e, mesh, model),
            _maybe(d0, mesh, fsdp),
            None,
        ]
        return P(*spec)
    if last == "router":
        lead = len(shape) - 2
        return P(*([None] * lead),
                 _maybe(shape[-2], mesh, fsdp), None)
    if last == "conv_w":
        lead = len(shape) - 2
        return P(*([None] * lead), None, _maybe(shape[-1], mesh, model))
    if last == "r":
        # sLSTM recurrent [.., H, hd, 4hd]: REPLICATED — it is consumed once
        # per timestep inside a 4096-step lax.scan; any sharding here turns
        # into one collective per timestep (measured: ~1e12 B/step).  The
        # table is small (<=100 MB), replication is the right trade.
        return P()

    if len(shape) >= 2:
        lead = len(shape) - 2
        d_in, d_out = shape[-2:]
        if last in _REVERSED:
            return P(*([None] * lead),
                     _maybe(d_in, mesh, model), _maybe(d_out, mesh, fsdp))
        return P(*([None] * lead),
                 _maybe(d_in, mesh, fsdp), _maybe(d_out, mesh, model))
    return P()


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return _axes_of(mesh, ("pod", "data"))


def tree_param_specs(params_shape: PyTree, mesh: Mesh,
                     mode: str = "tp") -> PyTree:
    """Specs for a pytree of params (or matching optimizer state).

    Kneaded serving leaves are handled as units, never field-by-field: a
    :class:`~repro.core.kneading.KneadedWeight` replicates whole (its packed
    planes/signs and schedule arrays are one indivisible kernel program —
    the projection-name rules above would otherwise try to TP-shard the
    uint32 plane words, splitting a work list from the tiles it indexes),
    a :class:`~repro.core.schedule.ShardedKneadedWeight` keeps its leading
    shard axis on "model", and a stacked
    :class:`~repro.core.schedule.ShardedStackedKneadedWeight` keeps its
    shard axis (axis 1, behind the scan-layer axis) on "model" (the
    placement :func:`kneaded_param_specs` defines).
    """
    from repro.core.kneading import KneadedWeight
    from repro.core.schedule import (ShardedKneadedWeight,
                                     ShardedStackedKneadedWeight)

    kinds = (KneadedWeight, ShardedKneadedWeight)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params_shape, is_leaf=lambda x: isinstance(x, kinds))
    specs = []
    for path, leaf in flat:
        # tile_slot is the whole-weight tile permutation the epilogue
        # gather reads — replicated, not shard-split (it indexes across
        # every shard's output slab)
        if isinstance(leaf, ShardedStackedKneadedWeight):
            specs.append(dataclasses.replace(
                jax.tree.map(lambda _: P(None, "model"), leaf),
                tile_slot=P()))
            continue
        if isinstance(leaf, ShardedKneadedWeight):
            specs.append(dataclasses.replace(
                jax.tree.map(lambda _: P("model"), leaf), tile_slot=P()))
            continue
        if isinstance(leaf, KneadedWeight):
            specs.append(jax.tree.map(lambda _: P(), leaf))
            continue
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        p = "/".join(str(k) for k in keys)
        specs.append(param_spec(p, tuple(leaf.shape), mesh, mode))
    return jax.tree_util.tree_unflatten(treedef, specs)


def tree_shardings(params_shape: PyTree, mesh: Mesh,
                   mode: str = "tp") -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_param_specs(params_shape, mesh, mode))


# ------------------------------------------------------- kneaded CNN serving

def kneaded_param_specs(tree: PyTree, axis: str = "model",
                        mesh: Optional[Mesh] = None) -> PyTree:
    """PartitionSpecs for a kneaded param tree (docs/DESIGN.md §5, §8, §13).

    :class:`~repro.core.schedule.ShardedKneadedWeight` leaves stack one
    weight/schedule slab per device on their leading shard axis — every
    array field gets ``P(axis)`` so device *i* holds shard *i*'s planes,
    signs, scales, AND compacted work lists (the schedule shards with the
    weight; there is no replicated metadata to walk).  Stacked
    :class:`~repro.core.schedule.ShardedStackedKneadedWeight` leaves carry
    the scan-layer axis in front (``[L, S, ...]``) and get
    ``P(None, axis)`` — the layer axis is never sharded (it is the
    ``lax.scan`` slice axis), the shard axis maps one slab per device.
    Kneaded MoE expert banks (plain ``KneadedWeight`` with ``[L, E, ...]``
    arrays, i.e. 5-dim planes) place whole experts on the "expert" mesh
    axis when ``mesh`` has one that divides E — every array field gets
    ``P(None, "expert")`` (layer axis scanned, expert axis sharded, the
    per-expert weight/schedule slabs replicated over "model").
    Other unsharded leaves (biases, float weights, unsharded
    ``KneadedWeight``) replicate: they are tiny or consumed by every
    device's epilogue.
    """
    from repro.core.kneading import KneadedWeight
    from repro.core.schedule import (ShardedKneadedWeight,
                                     ShardedStackedKneadedWeight)
    has_expert = mesh is not None and "expert" in mesh.axis_names \
        and mesh.shape["expert"] > 1

    def spec(leaf):
        # tile_slot replicates: it is the whole-weight tile permutation
        # the post-kernel gather indexes across all shards' output slabs
        if isinstance(leaf, ShardedStackedKneadedWeight):
            return dataclasses.replace(
                jax.tree.map(lambda _: P(None, axis), leaf), tile_slot=P())
        if isinstance(leaf, ShardedKneadedWeight):
            return dataclasses.replace(
                jax.tree.map(lambda _: P(axis), leaf), tile_slot=P())
        if (isinstance(leaf, KneadedWeight) and leaf.planes.ndim >= 5
                and has_expert
                and leaf.planes.shape[1] % mesh.shape["expert"] == 0):
            return jax.tree.map(lambda _: P(None, "expert"), leaf)
        return jax.tree.map(lambda _: P(), leaf)

    return jax.tree.map(
        spec, tree,
        is_leaf=lambda x: isinstance(x, (KneadedWeight,
                                         ShardedKneadedWeight)))


def kneaded_shardings(tree: PyTree, mesh: Mesh,
                      axis: str = "model") -> PyTree:
    """NamedShardings matching :func:`kneaded_param_specs` — pass straight to
    ``jax.device_put`` to place a sharded kneaded checkpoint on the mesh."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        kneaded_param_specs(tree, axis, mesh=mesh),
                        is_leaf=lambda x: isinstance(x, P))


def cache_spec_sharding(cache_shape: PyTree, mesh: Mesh,
                        batch: int) -> PyTree:
    """Decode caches: batch axis over (pod, data); the (large) seq axis of
    attention KV caches additionally over "model" (nemotron's kv=8 heads
    cannot shard 16 ways, the 32k seq axis always can).

    Attention caches are [stack..., B, S, KV, hd]; SSM/conv states are
    [stack..., B, ...] and shard on batch only.  The batch dim is located as
    the first dim equal to ``batch``.
    """
    b_axes = batch_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None

    def spec(leaf):
        shp = tuple(leaf.shape)
        s = [None] * len(shp)
        try:
            b_idx = shp.index(batch)
        except ValueError:
            return NamedSharding(mesh, P())
        s[b_idx] = _maybe(batch, mesh, b_axes)
        # [B, S, KV, hd] caches and [B, S, KV] scale arrays: shard the big
        # seq axis over "model" as well
        is_kv = len(shp) - b_idx in (3, 4) and shp[b_idx + 1] >= 4096
        if is_kv and model and shp[b_idx + 1] % mesh.shape[model] == 0:
            s[b_idx + 1] = model
        return NamedSharding(mesh, P(*s))

    return jax.tree.map(spec, cache_shape)
