"""Deterministic, stateless, resumable synthetic token pipeline.

Production framing: every batch is a pure function of (seed, step), so
  * resume-after-failure = restart at the checkpointed step (no reader state),
  * elastic rescale = recompute the per-host slice for the new topology,
  * no host is ever a straggler on data (generation is O(batch) integer math).

The stream is a mixture of (a) a Zipfian unigram field and (b) short
arithmetic-progression motifs, giving a learnable next-token structure so
example training curves actually descend (examples/train_smollm.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_alpha: float = 1.1
    motif_period: int = 17


class SyntheticTokens:
    """Indexable by step; shardable by (host_index, host_count)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # precompute a Zipf CDF over the vocab (numpy, host-side, once)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_alpha)
        self._cdf = jnp.asarray(np.cumsum(p / p.sum()), jnp.float32)

    def _batch_key(self, step: int) -> jax.Array:
        return jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), step)

    def global_batch(self, step: int) -> Dict[str, jax.Array]:
        """The full [B, S+1] token block for ``step`` (labels = shift-by-1)."""
        cfg = self.cfg
        key = self._batch_key(step)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s = cfg.global_batch, cfg.seq_len + 1
        u = jax.random.uniform(k1, (b, s))
        zipf = jnp.searchsorted(self._cdf, u).astype(jnp.int32)
        # motif: deterministic arithmetic progression inserted periodically
        start = jax.random.randint(k2, (b, 1), 0, cfg.vocab_size)
        stride = jax.random.randint(k3, (b, 1), 1, 7)
        pos = jnp.arange(s)[None, :]
        motif = (start + stride * pos) % cfg.vocab_size
        use_motif = (pos % cfg.motif_period) < (cfg.motif_period // 2)
        toks = jnp.where(use_motif, motif, zipf).astype(jnp.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_batch(self, step: int, host_index: int,
                   host_count: int) -> Dict[str, jax.Array]:
        """This host's contiguous slice of the global batch."""
        full = self.global_batch(step)
        per = self.cfg.global_batch // host_count
        lo = host_index * per
        return jax.tree.map(lambda x: x[lo:lo + per], full)
