"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
           manifest.json        {step, tree structure, leaf dtypes/shapes}
           leaf_<i>.npy         one file per leaf (host-local full array)

Durability: writes go to ``step_<N>.tmp`` and are atomically renamed, so a
crash mid-save never corrupts the latest checkpoint.  ``AsyncCheckpointer``
runs the serialization on a worker thread (training continues; the paper's
fault-tolerance requirement at 1000-node scale is checkpoint/restart — see
runtime.fault_tolerance for the restart side).

Elastic restore: leaves are stored unsharded; on restore they are placed
with ``jax.device_put`` against the *current* mesh's shardings, so the same
checkpoint restores onto 1 CPU, one pod, or two pods.

Integrity: every leaf's CRC32 (over the exact bytes written to disk) is
recorded in the manifest at save time and verified on restore — a truncated
or bit-flipped ``leaf_<i>.npy`` raises :class:`CheckpointCorrupt` naming the
leaf, instead of ``np.load`` garbage silently entering the restored tree
(the serving-resilience fault model of docs/DESIGN.md §10).
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

PyTree = Any

# numpy cannot serialize bf16 natively; store as uint16 + manifest dtype
_VIEW_DTYPES = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


class CheckpointCorrupt(RuntimeError):
    """A checkpoint leaf failed its integrity check on restore."""


def _flatten(tree: PyTree):
    return jax.tree_util.tree_flatten(tree)


def save(path: str | pathlib.Path, step: int, tree: PyTree) -> pathlib.Path:
    path = pathlib.Path(path)
    final = path / f"step_{step:08d}"
    tmp = path / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dt = str(arr.dtype)
        stored = arr.view(_VIEW_DTYPES[dt][1]) if dt in _VIEW_DTYPES else arr
        np.save(tmp / f"leaf_{i}.npy", stored)
        crc = zlib.crc32(np.ascontiguousarray(stored).tobytes())
        manifest["leaves"].append(
            {"dtype": dt, "shape": list(arr.shape), "crc32": crc})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic publish
    return final


def latest_step(path: str | pathlib.Path) -> Optional[int]:
    path = pathlib.Path(path)
    if not path.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in path.glob("step_*")
             if not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(path: str | pathlib.Path, step: int, like: PyTree,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of ``like``; optionally re-shard onto a
    (possibly different) mesh — the elastic-rescale path.

    Every leaf is verified against its manifest CRC32 before entering the
    tree; a missing, truncated, or bit-flipped file raises
    :class:`CheckpointCorrupt` naming the leaf index.  Manifests written
    before CRCs existed restore without verification (best effort).
    """
    d = pathlib.Path(path) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(like)
    if len(manifest["leaves"]) != len(leaves):
        raise CheckpointCorrupt(
            f"{d}: manifest records {len(manifest['leaves'])} leaves but "
            f"the restore target has {len(leaves)}")
    out = []
    for i, leaf in enumerate(leaves):
        fname = d / f"leaf_{i}.npy"
        entry = manifest["leaves"][i]
        try:
            arr = np.load(fname)
        except Exception as exc:  # noqa: BLE001 — np.load raises a zoo of
            # types on truncation (ValueError/EOFError/OSError); all mean
            # the same thing to the caller: this leaf is unreadable.
            raise CheckpointCorrupt(
                f"{d}: leaf {i} ({fname.name}) unreadable — "
                f"{type(exc).__name__}: {exc}") from exc
        want_crc = entry.get("crc32")
        if want_crc is not None:
            got_crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if got_crc != want_crc:
                raise CheckpointCorrupt(
                    f"{d}: leaf {i} ({fname.name}) CRC mismatch — "
                    f"stored {want_crc:#010x}, recomputed {got_crc:#010x} "
                    f"(dtype={entry['dtype']}, shape={entry['shape']})")
        dt = entry["dtype"]
        if dt in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[dt][0])
        if list(arr.shape) != list(entry["shape"]):
            raise CheckpointCorrupt(
                f"{d}: leaf {i} shape {list(arr.shape)} != manifest "
                f"{entry['shape']}")
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread; at most one in flight."""

    def __init__(self, path: str | pathlib.Path):
        self.path = pathlib.Path(path)
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save(self, step: int, tree: PyTree, block: bool = False):
        self.wait()
        # device_get on the caller thread (cheap on CPU; on TPU this is the
        # D2H copy) so the worker only does file IO.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            save(self.path, step, host_tree)
            self.last_saved = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
