"""LanguageModel: one assembly covering all six assigned families.

  dense   — scan over (attn + mlp) layers                 (llama3, smollm,
                                                           phi3, nemotron)
  moe     — scan over (attn + moe [+ dense residual])     (arctic, qwen3-moe)
  vlm     — scan over groups of (gated cross-attn + k self layers)
                                                           (llama-3.2-vision)
  hybrid  — scan over mamba2 blocks, shared attn block every N
                                                           (zamba2)
  ssm     — scan over groups of (k mLSTM + 1 sLSTM)       (xlstm)
  encdec  — encoder self-attn stack + decoder w/ cross-attn
                                                           (whisper; conv
                                                            frontend stubbed)

Execution regimes: ``loss``/``logits`` (teacher forcing), ``prefill``
(returns KV/state caches), ``decode_step`` (one token).  All stacks scan over
layers with stacked params (HLO size O(1) in depth) and remat the scan body
when ``cfg.remat``.  Cross-entropy is computed in sequence chunks so the
[B, S, vocab] logits tensor never materializes (vocab up to 256k).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks, layers, ssm

PyTree = object


def _split_keys(key, n):
    return list(jax.random.split(key, n))


def _stack_init(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn, prevent_cse=False) if cfg.remat else fn


class LanguageModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            assert cfg.num_heads % cfg.num_kv_heads == 0, cfg

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict:
        cfg = self.cfg
        keys = _split_keys(key, 8)
        params: Dict = {
            "embed": layers.dense_init(keys[0], cfg.vocab_size, cfg.d_model),
            "final_norm": layers.norm_init(cfg.d_model, cfg.norm),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = layers.dense_init(
                keys[1], cfg.d_model, cfg.vocab_size)
        fam = cfg.family
        if fam in ("dense", "moe"):
            def layer_init(k):
                k1, k2 = jax.random.split(k)
                p = {"attn": blocks.attn_init(k1, cfg)}
                if fam == "moe":
                    p["moe"] = blocks.moe_init(k2, cfg)
                else:
                    p["mlp"] = blocks.mlp_init(k2, cfg)
                return p
            params["layers"] = _stack_init(layer_init, keys[2], cfg.num_layers)
        elif fam == "vlm":
            g = cfg.num_layers // cfg.cross_attn_every
            inner = cfg.cross_attn_every - 1

            def self_init(k):
                k1, k2 = jax.random.split(k)
                return {"attn": blocks.attn_init(k1, cfg),
                        "mlp": blocks.mlp_init(k2, cfg)}

            def group_init(k):
                k1, k2, k3 = jax.random.split(k, 3)
                return {
                    "cross": blocks.attn_init(k1, cfg, cross=True),
                    "cross_mlp": blocks.mlp_init(k2, cfg),
                    "cross_gate": jnp.zeros((), jnp.float32),
                    "selfs": _stack_init(self_init, k3, inner),
                }
            params["groups"] = _stack_init(group_init, keys[2], g)
        elif fam == "hybrid":
            params["layers"] = _stack_init(
                lambda k: ssm.mamba2_init(k, cfg), keys[2], cfg.num_layers)
            params["shared_attn"] = blocks.attn_init(keys[3], cfg)
            params["shared_mlp"] = blocks.mlp_init(keys[4], cfg)
        elif fam == "ssm":
            g = cfg.num_layers // cfg.slstm_every
            inner = cfg.slstm_every - 1

            def group_init(k):
                k1, k2 = jax.random.split(k)
                return {"mlstm": _stack_init(
                            lambda kk: ssm.mlstm_init(kk, cfg), k1, inner),
                        "slstm": ssm.slstm_init(k2, cfg)}
            params["groups"] = _stack_init(group_init, keys[2], g)
        elif fam == "encdec":
            def enc_init(k):
                k1, k2 = jax.random.split(k)
                return {"attn": blocks.attn_init(k1, cfg),
                        "mlp": blocks.mlp_init(k2, cfg)}

            def dec_init(k):
                k1, k2, k3 = jax.random.split(k, 3)
                return {"attn": blocks.attn_init(k1, cfg),
                        "cross": blocks.attn_init(k2, cfg, cross=True),
                        "mlp": blocks.mlp_init(k3, cfg)}
            params["encoder"] = {
                "layers": _stack_init(enc_init, keys[2], cfg.encoder_layers),
                "pos_embed": layers.dense_init(
                    keys[3], cfg.encoder_seq, cfg.d_model),
                "final_norm": layers.norm_init(cfg.d_model, cfg.norm),
            }
            params["layers"] = _stack_init(dec_init, keys[4], cfg.num_layers)
            params["dec_pos_embed"] = layers.dense_init(
                keys[5], 32_768, cfg.d_model)   # learned pos up to 32k ctx
        else:
            raise ValueError(fam)
        return params

    # ------------------------------------------------------- full-seq trunk
    def _embed(self, params, tokens):
        cfg = self.cfg
        h = jnp.take(params["embed"], tokens, axis=0)
        h = h.astype(jnp.dtype(cfg.dtype))
        from repro.models.blocks import res_constrain
        return res_constrain(h, cfg)

    def _encode(self, params, frames):
        """Whisper encoder over precomputed conv-frontend frames (stub)."""
        cfg = self.cfg
        h = frames.astype(jnp.dtype(cfg.dtype))
        h = h + params["encoder"]["pos_embed"][None, :h.shape[1]].astype(h.dtype)

        def body(carry, p_l):
            y, _ = blocks.attn_apply(p_l["attn"], carry, cfg,
                                     positions=None, causal=False)
            y = blocks.mlp_apply(p_l["mlp"], y, cfg)
            return y, None

        body = _maybe_remat(body, cfg)
        h, _ = jax.lax.scan(body, h, params["encoder"]["layers"])
        return layers.apply_norm(params["encoder"]["final_norm"], h, cfg.norm)

    def _trunk(self, params, h, positions, *, collect_cache: bool,
               cross_src: Optional[jax.Array] = None):
        """Full-sequence pass.  Returns (h, aux_loss, cache_or_None)."""
        cfg = self.cfg
        fam = cfg.family
        aux0 = jnp.zeros((), jnp.float32)

        if fam in ("dense", "moe"):
            def body(carry, p_l):
                h, aux = carry
                h, kv = blocks.attn_apply(p_l["attn"], h, cfg,
                                          positions=positions,
                                          return_kv=collect_cache)
                if fam == "moe":
                    h, a = blocks.moe_apply(p_l["moe"], h, cfg)
                    aux = aux + a
                else:
                    h = blocks.mlp_apply(p_l["mlp"], h, cfg)
                return (h, aux), kv
            body = _maybe_remat(body, cfg)
            (h, aux), kvs = jax.lax.scan(body, (h, aux0), params["layers"])
            return h, aux, ({"k": kvs[0], "v": kvs[1]} if collect_cache else None)

        if fam == "vlm":
            def group_body(carry, p_g):
                h, aux = carry
                y, ckv = blocks.attn_apply(p_g["cross"], h, cfg,
                                           positions=positions, causal=False,
                                           kv_src=cross_src,
                                           return_kv=collect_cache)
                gate = jnp.tanh(p_g["cross_gate"])
                h = (h.astype(jnp.float32)
                     + gate * (y - h).astype(jnp.float32)).astype(h.dtype)
                h = blocks.mlp_apply(p_g["cross_mlp"], h, cfg)

                def self_body(carry2, p_l):
                    h2, aux2 = carry2
                    h2, kv = blocks.attn_apply(p_l["attn"], h2, cfg,
                                               positions=positions,
                                               return_kv=collect_cache)
                    h2 = blocks.mlp_apply(p_l["mlp"], h2, cfg)
                    return (h2, aux2), kv
                (h, aux), kvs = jax.lax.scan(self_body, (h, aux),
                                             p_g["selfs"])
                return (h, aux), (ckv, kvs)
            group_body = _maybe_remat(group_body, cfg)
            (h, aux), (ckvs, kvss) = jax.lax.scan(group_body, (h, aux0),
                                                  params["groups"])
            cache = None
            if collect_cache:
                cache = {"cross_k": ckvs[0], "cross_v": ckvs[1],
                         "k": kvss[0], "v": kvss[1]}
            return h, aux, cache

        if fam == "hybrid":
            n_apps = int(np.ceil(cfg.num_layers / cfg.attn_every))

            def body(carry, xs):
                h, aux, kv_store = carry
                p_l, idx = xs
                is_attn = (idx % cfg.attn_every) == 0
                kvh, hd = cfg.num_kv_heads, cfg.hd
                zero_kv = jnp.zeros(h.shape[:2] + (kvh, hd),
                                    jnp.dtype(cfg.dtype))

                def attn_branch(h):
                    y, kv = blocks.attn_apply(
                        params["shared_attn"], h, cfg, positions=positions,
                        return_kv=True)
                    y = blocks.mlp_apply(params["shared_mlp"], y, cfg)
                    return y, kv

                def skip_branch(h):
                    return h, (zero_kv, zero_kv)

                # cond (not select): the shared block really is skipped on
                # non-attention layers — no wasted FLOPs in the compiled HLO.
                h, kv = jax.lax.cond(is_attn, attn_branch, skip_branch, h)
                if collect_cache:
                    app = idx // cfg.attn_every
                    ks_, vs_ = kv_store
                    ks_ = jnp.where(is_attn, ks_.at[app].set(kv[0]), ks_)
                    vs_ = jnp.where(is_attn, vs_.at[app].set(kv[1]), vs_)
                    kv_store = (ks_, vs_)
                h, (conv_st, ssm_st) = ssm.mamba2_apply(p_l, h, cfg)
                ys = (conv_st, ssm_st) if collect_cache else None
                return (h, aux, kv_store), ys
            b_sz, s_len = h.shape[0], h.shape[1]
            kv0 = None
            if collect_cache:
                kvh, hd = cfg.num_kv_heads, cfg.hd
                kv0 = (jnp.zeros((n_apps, b_sz, s_len, kvh, hd),
                                 jnp.dtype(cfg.dtype)),
                       jnp.zeros((n_apps, b_sz, s_len, kvh, hd),
                                 jnp.dtype(cfg.dtype)))
            body = _maybe_remat(body, cfg)
            (h, aux, kv0), states = jax.lax.scan(
                body, (h, aux0, kv0),
                (params["layers"], jnp.arange(cfg.num_layers)))
            cache = None
            if collect_cache:
                cache = {"k": kv0[0], "v": kv0[1],
                         "conv": states[0], "ssm": states[1]}
            return h, aux, cache

        if fam == "ssm":
            def group_body(carry, p_g):
                h, aux = carry

                def m_body(h2, p_l):
                    h2, st = ssm.mlstm_apply(p_l, h2, cfg)
                    return h2, st
                h, m_states = jax.lax.scan(m_body, h, p_g["mlstm"])
                h, s_state = ssm.slstm_apply(p_g["slstm"], h, cfg)
                return (h, aux), (m_states, s_state)
            group_body = _maybe_remat(group_body, cfg)
            (h, aux), states = jax.lax.scan(group_body, (h, aux0),
                                            params["groups"])
            cache = None
            if collect_cache:
                cache = {"mlstm": states[0], "slstm": states[1]}
            return h, aux, cache

        if fam == "encdec":
            def body(carry, p_l):
                h, aux = carry
                h, kv = blocks.attn_apply(p_l["attn"], h, cfg,
                                          positions=positions,
                                          return_kv=collect_cache)
                hc, ckv = blocks.attn_apply(p_l["cross"], h, cfg,
                                            positions=positions, causal=False,
                                            kv_src=cross_src,
                                            return_kv=collect_cache)
                h = hc
                h = blocks.mlp_apply(p_l["mlp"], h, cfg)
                return (h, aux), (kv, ckv)
            body = _maybe_remat(body, cfg)
            (h, aux), (kvs, ckvs) = jax.lax.scan(body, (h, aux0),
                                                 params["layers"])
            cache = None
            if collect_cache:
                cache = {"k": kvs[0], "v": kvs[1],
                         "cross_k": ckvs[0], "cross_v": ckvs[1]}
            return h, aux, cache

        raise ValueError(fam)

    # ------------------------------------------------------------- logits
    def _positions(self, tokens):
        b, s = tokens.shape
        return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def _hidden(self, params, batch, collect_cache=False):
        cfg = self.cfg
        tokens = batch["tokens"]
        h = self._embed(params, tokens)
        positions = self._positions(tokens)
        cross_src = None
        if cfg.family == "encdec":
            cross_src = self._encode(params, batch["frames"])
            h = h + params["dec_pos_embed"][None, :h.shape[1]].astype(h.dtype)
        elif cfg.family == "vlm":
            cross_src = batch["image_embeds"].astype(jnp.dtype(cfg.dtype))
        h, aux, cache = self._trunk(params, h, positions,
                                    collect_cache=collect_cache,
                                    cross_src=cross_src)
        h = layers.apply_norm(params["final_norm"], h, cfg.norm)
        return h, aux, cache

    def _unembed_w(self, params):
        return (params["embed"].T if self.cfg.tie_embeddings
                else params["unembed"])

    def logits(self, params, batch) -> jax.Array:
        h, _, _ = self._hidden(params, batch)
        return layers.matmul_any(h, self._unembed_w(params),
                                 jnp.dtype(self.cfg.dtype),
                                 impl=self.cfg.impl,
                                 skip_activations=self.cfg.activation_skip)

    def loss(self, params, batch, loss_chunk: int = 0) -> jax.Array:
        """Cross entropy + MoE aux.  The vocab matmul runs in bf16 with f32
        softmax statistics.  Unchunked by default: the [tokens, V] logits are
        modest per device under both profiles (tp: V is model-sharded; dp:
        per-device tokens are small), and chunking via lax.scan forces a
        per-chunk f32 all-reduce of the unembed gradient (measured +14 GiB
        per device per step on llama3 — §Perf iteration log).  Pass
        ``loss_chunk`` > 0 for the memory-constrained chunked path."""
        cfg = self.cfg
        h, aux, _ = self._hidden(params, batch)
        labels = batch["labels"]
        b, s, d = h.shape
        w = self._unembed_w(params)

        def ce(hc, lc):
            logits = layers.matmul_any(hc, w, jnp.dtype(cfg.dtype))
            logits = logits.astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[..., None],
                                       axis=-1)[..., 0]
            return jnp.sum(logz - gold)

        if not loss_chunk or s % loss_chunk:
            return ce(h, labels) / (b * s) + aux
        c = loss_chunk
        h_ch = jnp.moveaxis(h.reshape(b, s // c, c, d), 1, 0)
        l_ch = jnp.moveaxis(labels.reshape(b, s // c, c), 1, 0)
        total, _ = jax.lax.scan(
            lambda acc, xs: (acc + ce(*xs), None),
            jnp.zeros((), jnp.float32), (h_ch, l_ch))
        return total / (b * s) + aux

    # ------------------------------------------------------------- serving
    def prefill(self, params, batch) -> Tuple[jax.Array, PyTree]:
        """Returns (last-token logits [B, V], cache)."""
        h, _, cache = self._hidden(params, batch, collect_cache=True)
        if (self.cfg.kv_cache_bits == 8
                and self.cfg.family in ("dense", "moe")):
            k8, ks = layers.quantize_kv(cache["k"])
            v8, vs = layers.quantize_kv(cache["v"])
            cache = {"k": k8, "v": v8, "k_scale": ks, "v_scale": vs}
        last = h[:, -1]
        logits = layers.matmul_any(last, self._unembed_w(params),
                                   jnp.dtype(self.cfg.dtype),
                                   impl=self.cfg.impl,
                                   skip_activations=self.cfg.activation_skip)
        # pad KV caches to max length happens in inference.engine; here the
        # cache covers the prefilled prefix exactly.
        return logits, cache

    def cache_spec(self, batch: int, max_len: int) -> PyTree:
        """ShapeDtypeStructs of the decode cache (dry-run input stand-ins)."""
        cfg = self.cfg
        fam = cfg.family
        dt = jnp.dtype(cfg.dtype)
        kvh, hd, L = cfg.num_kv_heads, cfg.hd, cfg.num_layers
        kv = lambda n, s: jax.ShapeDtypeStruct((n, batch, s, kvh, hd), dt)
        if fam in ("dense", "moe"):
            if cfg.kv_cache_bits == 8:
                kv8 = lambda n, s: jax.ShapeDtypeStruct(
                    (n, batch, s, kvh, hd), jnp.int8)
                sc = lambda n, s: jax.ShapeDtypeStruct(
                    (n, batch, s, kvh), jnp.float32)
                return {"k": kv8(L, max_len), "v": kv8(L, max_len),
                        "k_scale": sc(L, max_len), "v_scale": sc(L, max_len)}
            return {"k": kv(L, max_len), "v": kv(L, max_len)}
        if fam == "vlm":
            g = L // cfg.cross_attn_every
            inner = cfg.cross_attn_every - 1
            kv_self = jax.ShapeDtypeStruct(
                (g, inner, batch, max_len, kvh, hd), dt)
            kv_cross = jax.ShapeDtypeStruct(
                (g, batch, cfg.num_image_tokens, kvh, hd), dt)
            return {"k": kv_self, "v": kv_self,
                    "cross_k": kv_cross, "cross_v": kv_cross}
        if fam == "encdec":
            enc = jax.ShapeDtypeStruct(
                (L, batch, cfg.encoder_seq, kvh, hd), dt)
            return {"k": kv(L, max_len), "v": kv(L, max_len),
                    "cross_k": enc, "cross_v": enc}
        if fam == "hybrid":
            n_apps = int(np.ceil(L / cfg.attn_every))
            conv, state = ssm.mamba2_cache_spec(cfg, batch)
            stack = lambda sds, n: jax.ShapeDtypeStruct((n,) + sds.shape,
                                                        sds.dtype)
            return {"k": kv(n_apps, max_len), "v": kv(n_apps, max_len),
                    "conv": stack(conv, L), "ssm": stack(state, L)}
        if fam == "ssm":
            g = L // cfg.slstm_every
            inner = cfg.slstm_every - 1
            m = ssm.mlstm_cache_spec(cfg, batch)
            s = ssm.slstm_cache_spec(cfg, batch)
            stack2 = lambda sds: jax.ShapeDtypeStruct((g, inner) + sds.shape,
                                                      sds.dtype)
            stack1 = lambda sds: jax.ShapeDtypeStruct((g,) + sds.shape,
                                                      sds.dtype)
            return {"mlstm": stack2(m), "slstm": tuple(stack1(x) for x in s)}
        raise ValueError(fam)

    def decode_step(self, params, token, pos, cache):
        """One token: token [B, 1], pos [B] (index of the new token).

        Returns (logits [B, V], updated cache)."""
        cfg = self.cfg
        fam = cfg.family
        h = self._embed(params, token)
        if fam == "encdec":
            h = h + jnp.take(params["dec_pos_embed"], pos, axis=0)[:, None]

        # All decode scans below keep the big caches in the scan CARRY and
        # update them with dynamic_update_slice on the (unsharded) stack
        # axis.  Passing caches as xs/ys instead would double-buffer them
        # (input stack + collected output stack) — measured +9.6 GiB/device
        # on nemotron decode_32k.  Read-only caches (cross-attn KV) stay xs.
        def _upd(store, new, *idx):
            new = new.astype(store.dtype)
            return jax.lax.dynamic_update_slice(
                store, new[(None,) * len(idx)], idx + (0,) * new.ndim)

        if fam in ("dense", "moe"):
            quant_kv = "k_scale" in cache

            def body(carry, xs):
                h, aux, store = carry
                p_l, idx = xs
                slices = tuple(jax.lax.dynamic_index_in_dim(c, idx, 0, False)
                               for c in store)
                h, new_slices = blocks.attn_apply(p_l["attn"], h, cfg,
                                                  positions=None,
                                                  cache=slices, pos=pos)
                store = tuple(_upd(c, n, idx)
                              for c, n in zip(store, new_slices))
                if fam == "moe":
                    h, a = blocks.moe_apply(p_l["moe"], h, cfg)
                    aux = aux + a
                else:
                    h = blocks.mlp_apply(p_l["mlp"], h, cfg)
                return (h, aux, store), None
            store0 = ((cache["k"], cache["v"], cache["k_scale"],
                       cache["v_scale"]) if quant_kv
                      else (cache["k"], cache["v"]))
            (h, _, store), _ = jax.lax.scan(
                body, (h, jnp.zeros((), jnp.float32), store0),
                (params["layers"], jnp.arange(cfg.num_layers)))
            cache = ({"k": store[0], "v": store[1], "k_scale": store[2],
                      "v_scale": store[3]} if quant_kv
                     else {"k": store[0], "v": store[1]})
        elif fam == "vlm":
            inner = cfg.cross_attn_every - 1

            def group_body(carry, xs):
                h, k_all, v_all = carry
                p_g, ck, cv, g_idx = xs
                y, _ = blocks.attn_apply(p_g["cross"], h, cfg,
                                         positions=pos[:, None],
                                         causal=False, kv_const=(ck, cv))
                gate = jnp.tanh(p_g["cross_gate"])
                h = (h.astype(jnp.float32)
                     + gate * (y - h).astype(jnp.float32)).astype(h.dtype)
                h = blocks.mlp_apply(p_g["cross_mlp"], h, cfg)

                def self_body(carry2, xs2):
                    h2, k_all, v_all = carry2
                    p_l, i_idx = xs2
                    kc = jax.lax.dynamic_index_in_dim(
                        jax.lax.dynamic_index_in_dim(k_all, g_idx, 0, False),
                        i_idx, 0, False)
                    vc = jax.lax.dynamic_index_in_dim(
                        jax.lax.dynamic_index_in_dim(v_all, g_idx, 0, False),
                        i_idx, 0, False)
                    h2, (kc, vc) = blocks.attn_apply(
                        p_l["attn"], h2, cfg, positions=None,
                        cache=(kc, vc), pos=pos)
                    h2 = blocks.mlp_apply(p_l["mlp"], h2, cfg)
                    return (h2, _upd(k_all, kc, g_idx, i_idx),
                            _upd(v_all, vc, g_idx, i_idx)), None
                (h, k_all, v_all), _ = jax.lax.scan(
                    self_body, (h, k_all, v_all),
                    (p_g["selfs"], jnp.arange(inner)))
                return (h, k_all, v_all), None
            (h, k_new, v_new), _ = jax.lax.scan(
                group_body, (h, cache["k"], cache["v"]),
                (params["groups"], cache["cross_k"], cache["cross_v"],
                 jnp.arange(cfg.num_layers // cfg.cross_attn_every)))
            cache = dict(cache, k=k_new, v=v_new)
        elif fam == "encdec":
            def body(carry, xs):
                h, k_all, v_all = carry
                p_l, ck, cv, idx = xs
                kc = jax.lax.dynamic_index_in_dim(k_all, idx, 0, False)
                vc = jax.lax.dynamic_index_in_dim(v_all, idx, 0, False)
                h, (kc, vc) = blocks.attn_apply(p_l["attn"], h, cfg,
                                                positions=None,
                                                cache=(kc, vc), pos=pos)
                h, _ = blocks.attn_apply(p_l["cross"], h, cfg,
                                         positions=pos[:, None], causal=False,
                                         kv_const=(ck, cv))
                h = blocks.mlp_apply(p_l["mlp"], h, cfg)
                return (h, _upd(k_all, kc, idx), _upd(v_all, vc, idx)), None
            (h, k_new, v_new), _ = jax.lax.scan(
                body, (h, cache["k"], cache["v"]),
                (params["layers"], cache["cross_k"], cache["cross_v"],
                 jnp.arange(cfg.num_layers)))
            cache = dict(cache, k=k_new, v=v_new)
        elif fam == "hybrid":
            def body(carry, xs):
                h, ks_, vs_, conv_all, ssm_all = carry
                p_l, idx = xs
                is_attn = (idx % cfg.attn_every) == 0
                app = idx // cfg.attn_every
                kc = jax.lax.dynamic_index_in_dim(ks_, app, 0, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(vs_, app, 0, keepdims=False)

                def attn_branch(args):
                    h, kc, vc = args
                    y, (kc2, vc2) = blocks.attn_apply(
                        params["shared_attn"], h, cfg, positions=None,
                        cache=(kc, vc), pos=pos)
                    y = blocks.mlp_apply(params["shared_mlp"], y, cfg)
                    return y, kc2, vc2

                h, kc2, vc2 = jax.lax.cond(
                    is_attn, attn_branch, lambda a: a, (h, kc, vc))
                ks_ = _upd(ks_, kc2, app)
                vs_ = _upd(vs_, vc2, app)
                conv_c = jax.lax.dynamic_index_in_dim(conv_all, idx, 0, False)
                ssm_c = jax.lax.dynamic_index_in_dim(ssm_all, idx, 0, False)
                h, (conv_c, ssm_c) = ssm.mamba2_apply(
                    p_l, h, cfg, cache=(conv_c, ssm_c))
                return (h, ks_, vs_, _upd(conv_all, conv_c, idx),
                        _upd(ssm_all, ssm_c, idx)), None
            (h, k_new, v_new, conv_new, ssm_new), _ = jax.lax.scan(
                body, (h, cache["k"], cache["v"], cache["conv"],
                       cache["ssm"]),
                (params["layers"], jnp.arange(cfg.num_layers)))
            cache = {"k": k_new, "v": v_new, "conv": conv_new,
                     "ssm": ssm_new}
        elif fam == "ssm":
            def group_body(carry, xs):
                h, m_all, s_all = carry
                p_g, g_idx = xs

                def m_body(carry2, xs2):
                    h2, m_all = carry2
                    p_l, i_idx = xs2
                    st = jax.lax.dynamic_index_in_dim(
                        jax.lax.dynamic_index_in_dim(m_all, g_idx, 0, False),
                        i_idx, 0, False)
                    h2, st2 = ssm.mlstm_apply(p_l, h2, cfg, cache=st)
                    return (h2, _upd(m_all, st2, g_idx, i_idx)), None
                (h, m_all), _ = jax.lax.scan(
                    m_body, (h, m_all),
                    (p_g["mlstm"], jnp.arange(cfg.slstm_every - 1)))
                s_st = tuple(
                    jax.lax.dynamic_index_in_dim(s, g_idx, 0, False)
                    for s in s_all)
                h, s_new = ssm.slstm_apply(p_g["slstm"], h, cfg, cache=s_st)
                s_all = tuple(_upd(s, n, g_idx)
                              for s, n in zip(s_all, s_new))
                return (h, m_all, s_all), None
            (h, m_new, s_new), _ = jax.lax.scan(
                group_body, (h, cache["mlstm"], cache["slstm"]),
                (params["groups"],
                 jnp.arange(cfg.num_layers // cfg.slstm_every)))
            cache = {"mlstm": m_new, "slstm": s_new}
        else:
            raise ValueError(fam)

        h = layers.apply_norm(params["final_norm"], h, cfg.norm)
        logits = layers.matmul_any(h[:, 0], self._unembed_w(params),
                                   jnp.dtype(cfg.dtype),
                                   impl=cfg.impl,
                                   skip_activations=cfg.activation_skip)
        return logits, cache
