"""Transformer blocks: GQA attention, dense MLP, and capacity-based MoE.

All blocks are functional: ``*_init(key, cfg) -> params`` and
``*_apply(params, x, ...) -> y``.  Params are plain dicts of f32 arrays so a
stack of layers can be created with vmap and scanned over.

MoE follows the expert-parallel design in docs/DESIGN.md §3: routing is computed
replicated (router weight is tiny), dispatch/expert-compute/combine run under
``shard_map`` with experts sharded on the "model" axis and one psum to
combine — the same reduction pattern as Megatron TP, so no extra collective
class is introduced.  Without a mesh the identical dispatch code runs with
all experts local (smoke tests).

Kneaded expert banks (docs/DESIGN.md §13) take a second serving path: when
``p["wi"]``/``p["wo"]`` are stacked :class:`~repro.core.kneading.KneadedWeight`
banks ([E, K, N] per layer), the capacity-padded dense einsum is replaced by a
per-expert walk — each local expert's routed rows ([cap, D], M <= 8 at decode)
run through the SAC kernel's decode-GEMV fast path with the activation-skip
mask computed from exactly those routed rows.  Experts shard over the
dedicated "expert" mesh axis (the "model" axis keeps N-sharding the dense
projections); slot routing and the f32 scatter-add combine are shared with
the dense path, so EP == all-local stays bit-exact through the psum.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import matmul_any
from repro.runtime import pspec

# ---------------------------------------------------------------------------
# Attention block
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, d_model: Optional[int] = None,
              cross: bool = False) -> dict:
    d = d_model or cfg.d_model
    hd, nh, nkv = cfg.hd, cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 5)
    p = {
        "ln": layers.norm_init(d, cfg.norm),
        "wq": layers.dense_init(ks[0], d, nh * hd),
        "wk": layers.dense_init(ks[1], d, nkv * hd),
        "wv": layers.dense_init(ks[2], d, nkv * hd),
        "wo": layers.dense_init(ks[3], nh * hd, d,
                                scale=0.02 / np.sqrt(2 * cfg.num_layers)),
    }
    if cfg.qk_norm:
        p["qnorm"] = jnp.ones((hd,), jnp.float32)
        p["knorm"] = jnp.ones((hd,), jnp.float32)
    if cross:
        p["ln_kv"] = layers.norm_init(d, cfg.norm)
    return p


def res_constrain(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Residual-stream constraint: batch-sharded always; sequence-parallel
    (Megatron SP: residuals sharded over "model" on the seq axis) when the
    config enables it, cutting the per-layer activation footprint (and remat
    carries) by the TP degree."""
    seq = "seq" if x.ndim >= 3 and cfg.sequence_parallel else None
    return pspec.constrain(x, *(["batch", seq] + [None] * (x.ndim - 2)))


def sp_gather(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Megatron-SP's explicit activation all-gather before a TP matmul.

    With the seq axis sharded over "model" THROUGH a matmul, the partitioner
    cannot also keep the weight TP-sharded on "model" — it falls back to a
    FULL weight all-gather (measured on nemotron train: f32[18432,73728]
    gathered per layer per microbatch, 3.9 TiB/device/step).  Re-gathering
    the (much smaller) activations here frees the model axis for the weight,
    restoring proper TP: AG(x over seq) + RS(y over seq) replaces the
    catastrophic weight gathers.  §Perf nemotron iteration."""
    if not cfg.sequence_parallel or not cfg.sp_matmul_gather or x.ndim < 3:
        return x
    return pspec.constrain(x, *(["batch"] + [None] * (x.ndim - 1)))


def _attn_shard_mode(cfg: ModelConfig):
    """How to shard attention tensors over the "model" axes.

    "kv" when the kv-head count divides the TP degree (fully local
    attention); else "hd" (head_dim sharded; the score contraction psums —
    ~8x less traffic than the replicated-head fallback GSPMD chooses on its
    own, which all-gathers q/k/v inside every flash-attention step).
    """
    mesh = pspec.current_mesh()
    if mesh is None:
        return None
    axes = [a for a in pspec.current_rules().get("model", ())
            if a in mesh.axis_names]
    n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if n <= 1:
        return None
    if cfg.num_kv_heads % n == 0:
        return "kv"
    # Two alternatives for kv_heads % TP != 0 were tried and REFUTED
    # (EXPERIMENTS.md §Perf, arctic iterations 5a/5b):
    #   "hd" (shard head_dim, psum scores): flash score blocks are
    #        cq*ck >> q/k/v chunks -> 4x MORE traffic (74s vs 19s);
    #   "q_heads" (shard padded G, replicate k/v): the un-constrained flash
    #        (m,l,o) carries re-gather per pair step -> 36s vs 19s.
    # GSPMD's replicated-head fallback is the best known layout here;
    # proper 2D flash sharding needs carry constraints — future work.
    return None


def _qkv(p, x, kv_src, cfg: ModelConfig, dtype):
    b = x.shape[0]
    hd, nh, nkv = cfg.hd, cfg.num_heads, cfg.num_kv_heads
    g = nh // nkv
    impl, skip = cfg.impl, cfg.activation_skip
    q = matmul_any(x, p["wq"], dtype, impl=impl,
                   skip_activations=skip).reshape(b, -1, nkv, g, hd)
    k = matmul_any(kv_src, p["wk"], dtype, impl=impl,
                   skip_activations=skip).reshape(b, -1, nkv, hd)
    v = matmul_any(kv_src, p["wv"], dtype, impl=impl,
                   skip_activations=skip).reshape(b, -1, nkv, hd)
    if cfg.qk_norm:
        q = layers.rms_head_norm(q, p["qnorm"])
        k = layers.rms_head_norm(k, p["knorm"])
    mode = _attn_shard_mode(cfg)
    if mode == "kv":
        q = pspec.constrain(q, "batch", None, "model", None, None)
        k = pspec.constrain(k, "batch", None, "model", None)
        v = pspec.constrain(v, "batch", None, "model", None)
    return q, k, v


def attn_apply(
    p,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    kv_src: Optional[jax.Array] = None,          # cross-attention source
    kv_const: Optional[Tuple[jax.Array, jax.Array]] = None,  # precomputed k,v
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,     # decode KV cache
    pos: Optional[jax.Array] = None,             # decode position [B]
    return_kv: bool = False,
):
    """Pre-norm attention block.  Returns (y, new_cache_or_kv_or_None)."""
    dtype = jnp.dtype(cfg.dtype)
    h = sp_gather(layers.apply_norm(p["ln"], x, cfg.norm), cfg)
    use_rope = cfg.positional == "rope"

    if cache is not None:                         # ---- decode step
        quant_kv = len(cache) == 4                # (k8, v8, k_scale, v_scale)
        if quant_kv:
            k_cache, v_cache, k_sc, v_sc = cache
        else:
            k_cache, v_cache = cache
        q, k_new, v_new = _qkv(p, h, h, cfg, dtype)
        if use_rope:
            posb = pos[:, None]
            q = layers.apply_rope(q, posb, cfg.rope_theta)
            k_new = layers.apply_rope(k_new, posb, cfg.rope_theta)
        # Cache write as a masked elementwise select, NOT dynamic_update_
        # slice: the cache seq axis is "model"-sharded at scale, and DUS on
        # a sharded axis forces an involuntary full rematerialization (SPMD
        # gathers the whole cache).  The where() lowers to a fully local
        # masked write on every shard.
        write = (jnp.arange(k_cache.shape[1])[None, :, None, None]
                 == pos[:, None, None, None])
        if quant_kv:
            # knead the cache like the weights: int8 codes + per-(pos, head)
            # scale; write codes and scales under the same mask
            (k8, ks_new), (v8, vs_new) = (layers.quantize_kv(k_new),
                                          layers.quantize_kv(v_new))
            k_cache = jnp.where(write, k8, k_cache)
            v_cache = jnp.where(write, v8, v_cache)
            k_sc = jnp.where(write[..., 0], ks_new, k_sc)
            v_sc = jnp.where(write[..., 0], vs_new, v_sc)
            k_read = k_cache.astype(jnp.float32) * k_sc[..., None]
            v_read = v_cache.astype(jnp.float32) * v_sc[..., None]
        else:
            k_cache = jnp.where(write, k_new.astype(k_cache.dtype), k_cache)
            v_cache = jnp.where(write, v_new.astype(v_cache.dtype), v_cache)
            k_read, v_read = k_cache, v_cache
        out = layers.decode_attention(q, k_read, v_read, pos,
                                      window=cfg.window)
        y = matmul_any(out.reshape(out.shape[0], 1, -1), p["wo"], dtype,
                       impl=cfg.impl, skip_activations=cfg.activation_skip)
        if quant_kv:
            return x + y, (k_cache, v_cache, k_sc, v_sc)
        return x + y, (k_cache, v_cache)

    if kv_const is not None:                      # ---- cross-attn w/ cached KV
        k, v = kv_const
        q, _, _ = _qkv(p, h, h[:, :1], cfg, dtype)  # kv path unused
        # no RoPE on cross-attention queries (positions are heterogeneous)
        out = layers.attend(q, k, v, causal=False, impl=cfg.attn_impl,
                            chunk=cfg.attn_chunk,
                            replicate_heads=cfg.flash_replicate_pin
                            and _attn_shard_mode(cfg) is None
                            and pspec.current_mesh() is not None)
    else:
        src = kv_src if kv_src is not None else h
        if kv_src is not None:
            src = layers.apply_norm(p["ln_kv"], src, cfg.norm) \
                if "ln_kv" in p else src
        q, k, v = _qkv(p, h, src, cfg, dtype)
        if use_rope and kv_src is None:
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
        out = layers.attend(q, k, v, causal=causal and kv_src is None,
                            impl=cfg.attn_impl, chunk=cfg.attn_chunk,
                            window=cfg.window,
                            replicate_heads=cfg.flash_replicate_pin
                            and _attn_shard_mode(cfg) is None
                            and pspec.current_mesh() is not None)
    b, s = out.shape[:2]
    y = matmul_any(out.reshape(b, s, -1), p["wo"], dtype, impl=cfg.impl,
                   skip_activations=cfg.activation_skip)
    y = res_constrain(x + y, cfg)
    if return_kv:
        return y, (k, v)
    return y, None


# ---------------------------------------------------------------------------
# Dense MLP block
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    f = d_ff or cfg.d_ff
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln": layers.norm_init(d, cfg.norm),
        "wo": layers.dense_init(k2, f, d,
                                scale=0.02 / np.sqrt(2 * cfg.num_layers)),
    }
    if cfg.activation == "swiglu":
        # SEPARATE gate/up projections, not a fused [D, 2F] + split: the
        # split of a "model"-sharded 2F dim makes the partitioner give up
        # on the TP layout entirely (measured on vlm train: full f32 weight
        # all-gathers, 1.1 TiB/device/step — §Perf iteration E).
        p["wi_gate"] = layers.dense_init(k1, d, f)
        p["wi_up"] = layers.dense_init(k3, d, f)
    else:
        p["wi"] = layers.dense_init(k1, d, f)
    return p


def _ffn(h, p, activation: str, dtype, impl: str = "int",
         skip: bool = False) -> jax.Array:
    if activation == "swiglu":
        u = (jax.nn.silu(matmul_any(h, p["wi_gate"], dtype, impl=impl,
                                    skip_activations=skip))
             * matmul_any(h, p["wi_up"], dtype, impl=impl,
                          skip_activations=skip))
    else:
        u = layers.activate(matmul_any(h, p["wi"], dtype, impl=impl,
                                       skip_activations=skip),
                            activation)
    return matmul_any(u, p["wo"], dtype, impl=impl, skip_activations=skip)


def mlp_apply(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dtype = jnp.dtype(cfg.dtype)
    h = sp_gather(layers.apply_norm(p["ln"], x, cfg.norm), cfg)
    y = _ffn(h, p, cfg.activation, dtype, impl=cfg.impl,
             skip=cfg.activation_skip)
    return res_constrain(x + y, cfg)


# ---------------------------------------------------------------------------
# MoE block (capacity-based dispatch, EP over "model")
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> dict:
    d, e = cfg.d_model, cfg.num_experts
    f = cfg.moe_dff or cfg.d_ff
    ks = jax.random.split(key, 4)
    wi_out = 2 * f if cfg.activation == "swiglu" else f
    p = {
        "ln": layers.norm_init(d, cfg.norm),
        "router": layers.dense_init(ks[0], d, e, scale=0.02),
        "wi": jax.vmap(lambda k: layers.dense_init(k, d, wi_out))(
            jax.random.split(ks[1], e)),
        "wo": jax.vmap(lambda k: layers.dense_init(
            k, f, d, scale=0.02 / np.sqrt(2 * cfg.num_layers)))(
            jax.random.split(ks[2], e)),
    }
    if cfg.dense_residual:
        p["dense"] = mlp_init(ks[3], cfg)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = int(np.ceil(n_tokens * cfg.top_k * cfg.capacity_factor
                      / cfg.num_experts))
    return max(1, min(n_tokens, max(cap, 4)))


def _split_quant(w):
    """Maybe-quantized weight -> (codes_or_float, scale_or_None, packed4)."""
    from repro.core.quantization import QuantizedTensor
    from repro.models.layers import PackedInt4
    if isinstance(w, QuantizedTensor):
        return w.q, w.scale, False
    if isinstance(w, PackedInt4):
        return w.packed, w.scale, True
    return w, None, False


def _expert_matmul(xg, q, scale, packed4, dtype):
    """[E, C, D] @ per-expert [E, D', F] with SAC epilogue scaling."""
    if packed4:
        from repro.kernels.kneaded_gemm.ref import unpack_int4
        q = jax.vmap(unpack_int4)(q)
    h = jnp.einsum("ecd,edf->ecf", xg.astype(dtype), q.astype(dtype),
                   preferred_element_type=dtype)
    if scale is not None:
        h = (h.astype(jnp.float32) * scale).astype(dtype)
    return h


def _route_slots(x2d, eids, gates, e_loc: int, e_offset, cap: int):
    """Capacity-slot routing shared by the dense and kneaded expert paths.

    Computes, for the local expert slice [e_loc], which token feeds each
    (expert, capacity) slot and gathers those rows.  Returns
    ``(xg [e_loc, cap, D], disp [e_loc*cap], slot_gate [e_loc*cap])``.
    Sharing this (and :func:`_combine_slots`) between the paths is
    load-bearing for bit-exactness: identical slot order means identical
    f32 scatter-add pairing in the combine, so kneaded EP == all-local
    reduces in the same order the dense path always has.
    """
    t, d = x2d.shape
    k = eids.shape[1]
    flat_e = eids.reshape(-1)                       # [T*k]
    flat_g = gates.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(t), k)
    local = flat_e - e_offset                       # [T*k] local expert index
    oh = jax.nn.one_hot(local, e_loc, dtype=jnp.int32)   # out-of-range -> 0
    position = jnp.cumsum(oh, axis=0) - oh               # slots used before me
    mypos = jnp.sum(position * oh, axis=1)
    valid = (oh.sum(axis=1) > 0) & (mypos < cap)
    slot = jnp.where(valid, local * cap + mypos, e_loc * cap)  # overflow bin
    # dispatch indices: which token feeds each (expert, capacity) slot
    disp = jnp.full((e_loc * cap + 1,), t, jnp.int32).at[slot].set(
        jnp.where(valid, tok_idx, t))[:-1]
    slot_gate = jnp.zeros((e_loc * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(valid, flat_g, 0.0))[:-1]
    x_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
    xg = x_pad[disp].reshape(e_loc, cap, d)              # gather
    return xg, disp, slot_gate


def _combine_slots(y, disp, slot_gate, t: int, out_dtype):
    """Gate-weighted f32 scatter-add of per-slot outputs back to tokens."""
    d = y.shape[-1]
    y_flat = y.reshape(-1, d).astype(jnp.float32) * slot_gate[:, None]
    out = jnp.zeros((t + 1, d), jnp.float32).at[disp].add(y_flat)[:-1]
    return out.astype(out_dtype)


def _dispatch_compute(x2d, eids, gates, wi, wi_scale, wo, wo_scale,
                      *, cfg: ModelConfig, e_offset, cap: int, dtype,
                      wi_packed4=False, wo_packed4=False):
    """Expert-compute for the local expert slice [e_loc] on local tokens.

    x2d [T, D]; eids/gates [T, k] global expert ids / combine weights;
    wi [e_loc, D, F'], wo [e_loc, F, D] (float or integer codes with
    per-channel scales — the quantized serving path).  Returns [T, D] (this
    shard's experts' contribution only — caller psums over "model").
    """
    t, _ = x2d.shape
    e_loc = wi.shape[0]
    xg, disp, slot_gate = _route_slots(x2d, eids, gates, e_loc, e_offset, cap)
    h = _expert_matmul(xg, wi, wi_scale, wi_packed4, dtype)
    if cfg.activation == "swiglu":
        gate_h, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate_h) * up
    else:
        h = layers.activate(h, cfg.activation)
    y = _expert_matmul(h, wo, wo_scale, wo_packed4, dtype)
    return _combine_slots(y, disp, slot_gate, t, x2d.dtype)


def _dispatch_compute_kneaded(x2d, eids, gates, kwi, kwo,
                              *, cfg: ModelConfig, e_offset, cap: int, dtype,
                              combine_dtype=None):
    """Kneaded expert-compute: per-expert SAC matmuls on the routed rows.

    ``kwi``/``kwo`` are per-layer expert banks — stacked
    :class:`~repro.core.kneading.KneadedWeight` with a leading local-expert
    axis ([e_loc, ...] arrays; scanning slices expert e's exact per-expert
    kneaded weight).  Instead of the capacity-padded [E, C, D] dense slab,
    each expert runs only its own gathered [cap, D] rows through
    ``matmul_any`` -> SAC: at decode cap <= 8, so this is the decode-GEMV
    fast path and the PR-9 activation-skip mask is computed from exactly
    the routed rows (unfilled capacity slots gather the zero pad row and
    contribute no K-tile presence — routing sparsity becomes skipped MXU
    passes for free).  Routing and combine are shared with the dense path
    (:func:`_route_slots` / :func:`_combine_slots`), so the f32 reduction
    order — and therefore bit-exactness of EP vs all-local through the
    psum — is unchanged.  ``combine_dtype`` overrides the combine output
    dtype: the EP shard function passes f32 so each shard's partial stays
    unrounded through the psum (a token's top-k experts can straddle
    shards — rounding per shard and again after the psum would double-round
    exactly those tokens; summing in f32 and rounding once after the psum
    reproduces the all-local reduction bit for bit).
    """
    t, _ = x2d.shape
    e_loc = kwi.planes.shape[0]
    if combine_dtype is None:
        combine_dtype = x2d.dtype
    xg, disp, slot_gate = _route_slots(x2d, eids, gates, e_loc, e_offset, cap)
    impl, skip = cfg.impl, cfg.activation_skip

    def expert_body(carry, ew):
        kwi_e, kwo_e, xg_e = ew
        h = matmul_any(xg_e, kwi_e, dtype, impl=impl, skip_activations=skip)
        if cfg.activation == "swiglu":
            gate_h, up = jnp.split(h, 2, axis=-1)
            h = jax.nn.silu(gate_h) * up
        else:
            h = layers.activate(h, cfg.activation)
        y_e = matmul_any(h, kwo_e, dtype, impl=impl, skip_activations=skip)
        return carry, y_e

    _, y = jax.lax.scan(expert_body, None, (kwi, kwo, xg))
    return _combine_slots(y, disp, slot_gate, t, combine_dtype)


def _moe_kneaded(h2, e2, g2, kwi, kwo, *, cfg: ModelConfig, mesh,
                 n_tokens: int, dtype):
    """Serve the kneaded expert banks, expert-parallel over "expert".

    The bank is sharded on the dedicated "expert" mesh axis when present
    (size > 1 and dividing E); the "model" axis keeps N-sharding the dense
    projections and simply replicates this computation.  Without an expert
    axis the identical dispatch runs with all experts local — the bit-exact
    oracle the EP acceptance tests compare against.
    """
    if mesh is None:
        # The serving engine installs its mesh via runtime.sharding's
        # threadlocal, not pspec.axis_rules — fall back so EP activates.
        from repro.runtime.sharding import current_serving_mesh
        mesh = current_serving_mesh()[0]
    e = cfg.num_experts
    cap = _capacity(n_tokens, cfg)
    ep = (mesh is not None and "expert" in mesh.axis_names
          and mesh.shape["expert"] > 1 and e % mesh.shape["expert"] == 0)
    if not ep:
        return _dispatch_compute_kneaded(h2, e2, g2, kwi, kwo, cfg=cfg,
                                         e_offset=0, cap=cap, dtype=dtype)
    from jax.experimental.shard_map import shard_map
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    e_loc = e // mesh.shape["expert"]

    def shard_fn(h_l, e_l, g_l, kwi_l, kwo_l):
        off = jax.lax.axis_index("expert") * e_loc
        # combine in f32 and round once after the psum: a token's top-k
        # experts can straddle expert shards, and per-shard rounding to the
        # activation dtype before the psum double-rounds those tokens vs
        # the all-local oracle
        y = _dispatch_compute_kneaded(h_l, e_l, g_l, kwi_l, kwo_l, cfg=cfg,
                                      e_offset=off, cap=cap, dtype=dtype,
                                      combine_dtype=jnp.float32)
        return jax.lax.psum(y, "expert").astype(h_l.dtype)

    # every bank array carries the (local) expert axis leading -> a uniform
    # P("expert") pytree spec shards dim 0 and replicates the rest
    bank_spec = jax.tree.map(lambda _: P("expert"), kwi)
    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(batch_axes, None), P(batch_axes, None),
                  P(batch_axes, None), bank_spec,
                  jax.tree.map(lambda _: P("expert"), kwo)),
        out_specs=P(batch_axes, None),
        check_rep=False,
    )(h2, e2, g2, kwi, kwo)


def moe_apply(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (y, aux_loss).  x: [B, S, D]."""
    dtype = jnp.dtype(cfg.dtype)
    b, s, d = x.shape
    # NB: no sp_gather here — the MoE shard_map's in_specs reshard the
    # tokens themselves; an explicit full-seq gather first was measured
    # 2.4x worse on arctic (EXPERIMENTS.md §Perf B5).
    h = layers.apply_norm(p["ln"], x, cfg.norm)
    logits = matmul_any(h, p["router"], jnp.float32)     # [B, S, E] replicated
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, eids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss (computed on replicated routing).
    density = jnp.mean(
        jax.nn.one_hot(eids, cfg.num_experts, dtype=jnp.float32), axis=(0, 1, 2))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = cfg.num_experts * jnp.sum(density * mean_prob) * cfg.router_aux_coef

    h2, e2, g2 = (h.reshape(b * s, d), eids.reshape(b * s, -1),
                  gates.reshape(b * s, -1))
    mesh = pspec.current_mesh()
    from repro.core import routing_stats
    from repro.core.kneading import KneadedWeight
    routing_stats.record_routing(e2, cfg.num_experts,
                                 _capacity(b * s, cfg))
    if isinstance(p["wi"], KneadedWeight):
        y2 = _moe_kneaded(h2, e2, g2, p["wi"], p["wo"], cfg=cfg, mesh=mesh,
                          n_tokens=b * s, dtype=dtype)
        y = y2.reshape(b, s, d)
        if cfg.dense_residual:
            dense_h = layers.apply_norm(p["dense"]["ln"], x, cfg.norm)
            y = y + _ffn(dense_h, p["dense"], cfg.activation, dtype,
                         impl=cfg.impl, skip=cfg.activation_skip)
        return res_constrain(x + y.astype(x.dtype), cfg), aux
    wi_q, wi_s, wi_p4 = _split_quant(p["wi"])
    wo_q, wo_s, wo_p4 = _split_quant(p["wo"])
    ep_axes = [a for a in ("model",) if mesh is not None
               and a in mesh.axis_names and mesh.shape[a] > 1]
    if not ep_axes:
        cap = _capacity(b * s, cfg)
        y2 = _dispatch_compute(h2, e2, g2, wi_q, wi_s, wo_q, wo_s, cfg=cfg,
                               e_offset=0, cap=cap, dtype=dtype,
                               wi_packed4=wi_p4, wo_packed4=wo_p4)
    else:
        from jax.experimental.shard_map import shard_map
        batch_axes = tuple(a for a in ("pod", "data")
                           if a in mesh.axis_names)
        n_batch_shards = int(np.prod([mesh.shape[a] for a in batch_axes])) or 1
        t_loc = (b * s) // n_batch_shards
        e_shards = mesh.shape["model"]
        e_loc = cfg.num_experts // e_shards
        cap = _capacity(t_loc, cfg)
        # weights/scales enter shard_map EP-sharded on the expert axis
        zero = jnp.zeros((), dtype)
        wi_s_arg = wi_s if wi_s is not None else zero
        wo_s_arg = wo_s if wo_s is not None else zero
        escale_spec = (P("model", None, None) if wi_s is not None else P())

        def shard_fn(h_l, e_l, g_l, wi_l, wis_l, wo_l, wos_l):
            off = jax.lax.axis_index("model") * e_loc
            y = _dispatch_compute(
                h_l, e_l, g_l, wi_l,
                wis_l if wi_s is not None else None,
                wo_l, wos_l if wo_s is not None else None,
                cfg=cfg, e_offset=off, cap=cap, dtype=dtype,
                wi_packed4=wi_p4, wo_packed4=wo_p4)
            return jax.lax.psum(y, "model")

        y2 = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(batch_axes, None), P(batch_axes, None),
                      P(batch_axes, None), P("model", None, None),
                      escale_spec, P("model", None, None), escale_spec),
            out_specs=P(batch_axes, None),
            check_rep=False,
        )(h2, e2, g2, wi_q, wi_s_arg, wo_q, wo_s_arg)
    y = y2.reshape(b, s, d)
    if cfg.dense_residual:
        dense_h = layers.apply_norm(p["dense"]["ln"], x, cfg.norm)
        y = y + _ffn(dense_h, p["dense"], cfg.activation, dtype,
                     impl=cfg.impl, skip=cfg.activation_skip)
    out = res_constrain(x + y.astype(x.dtype), cfg)
    return out, aux
