"""State-space / recurrent blocks: Mamba2 (SSD), mLSTM, sLSTM.

A single chunkwise-parallel primitive (`ssd_chunked`) serves both Mamba2 and
mLSTM — they share the algebra  h_t = a_t h_{t-1} + u_t (b_t outer) ;
y_t = c_t . h_t  with per-step scalar decay ``a_t`` per head.  The chunked
form scans over chunks (O(L/c) sequential steps) and is exact.

mLSTM's normalizer is carried by augmenting the value vector with a constant
1 column, so the same state matrix carries (C, n) — one primitive, two models.

sLSTM is inherently sequential (scalar memories + recurrent gate matrices);
it runs as a lax.scan over time, which is the honest TPU mapping (the paper's
sLSTM admits no chunkwise parallel form).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import matmul_any

# ---------------------------------------------------------------------------
# Chunkwise SSD primitive
# ---------------------------------------------------------------------------

def ssd_chunked(
    u: jax.Array,        # [B, L, H, p]  gated inputs (dt*x or i*v)
    b: jax.Array,        # [B, L, H, n]  input projections (B_t or k_t)
    c: jax.Array,        # [B, L, H, n]  output projections (C_t or q_t)
    log_a: jax.Array,    # [B, L, H]     per-step log decay, <= 0
    chunk: int,
    h0: Optional[jax.Array] = None,   # [B, H, p, n]
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B, L, H, p], h_final [B, H, p, n]).  Exact linear scan."""
    bsz, l, h, p = u.shape
    n = b.shape[-1]
    assert l % chunk == 0, (l, chunk)
    m = l // chunk
    f32 = jnp.float32
    u_, b_, c_, la_ = (x.astype(f32) for x in (u, b, c, log_a))
    u_ = u_.reshape(bsz, m, chunk, h, p)
    b_ = b_.reshape(bsz, m, chunk, h, n)
    c_ = c_.reshape(bsz, m, chunk, h, n)
    la_ = la_.reshape(bsz, m, chunk, h)
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), f32)

    def chunk_step(h_prev, xs):
        uc, bc, cc, lac = xs                        # [B, c, H, ...]
        cum = jnp.cumsum(lac, axis=1)               # [B, c, H] inclusive
        total = cum[:, -1]                          # [B, H]
        # intra-chunk: y[t] += sum_{s<=t} exp(cum[t]-cum[s]) (c_t.b_s) u_s
        rel = cum[:, :, None, :] - cum[:, None, :, :]          # [B, t, s, H]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]
        decay = jnp.where(tri, jnp.exp(rel), 0.0)
        scores = jnp.einsum("bthn,bshn->btsh", cc, bc) * decay
        y_intra = jnp.einsum("btsh,bshp->bthp", scores, uc)
        # inter-chunk: y[t] += c_t . (exp(cum[t]) h_prev)
        y_inter = jnp.einsum("bthn,bhpn->bthp", cc * jnp.exp(cum)[..., None],
                             h_prev)
        # state update: h = exp(total) h_prev + sum_s exp(total-cum[s]) u_s b_s
        carry_decay = jnp.exp(total - 0.0)[..., None, None]
        w = jnp.exp(total[:, None] - cum)                      # [B, c, H]
        h_new = (h_prev * carry_decay
                 + jnp.einsum("bshp,bshn,bsh->bhpn", uc, bc, w))
        return h_new, y_intra + y_inter

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (u_, b_, c_, la_))
    h_final, ys = jax.lax.scan(chunk_step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, l, h, p)
    return y.astype(u.dtype), h_final


def ssd_step(
    u: jax.Array,       # [B, H, p]
    b: jax.Array,       # [B, H, n]
    c: jax.Array,       # [B, H, n]
    log_a: jax.Array,   # [B, H]
    h: jax.Array,       # [B, H, p, n]
) -> Tuple[jax.Array, jax.Array]:
    """Single decode step of the same recurrence.

    The head axis is per-layer (`nh = d_inner // ssm_head_dim` for Mamba2,
    `num_heads` for mLSTM) and the cache must carry exactly that extent —
    a cache whose head axis was padded or built against a different head
    count silently broadcasts into garbage, so mismatches fail loudly here
    (the zamba2 hybrid-decode regression: an engine-side pad once stretched
    the state's head axis to the prompt length).
    """
    if h.shape[1] != u.shape[1]:
        raise ValueError(
            f"ssd_step state heads {h.shape[1]} != input heads {u.shape[1]}"
            " — the decode cache does not match this layer's head count")
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    h_new = h * a + jnp.einsum("bhp,bhn->bhpn", u.astype(jnp.float32),
                               b.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", c.astype(jnp.float32), h_new)
    return y.astype(u.dtype), h_new


# ---------------------------------------------------------------------------
# Depthwise causal conv (Mamba front conv)
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array,
                state: Optional[jax.Array] = None):
    """x [B, L, C], w [W, C] depthwise.  Returns (y, new_state [B, W-1, C])."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    segs = [xp[:, i:i + x.shape[1], :] * w[i] for i in range(width)]
    y = sum(segs)
    return jax.nn.silu(y), xp[:, -(width - 1):, :]


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba2_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    ks = jax.random.split(key, 4)
    conv_ch = di + 2 * n
    return {
        "ln": layers.norm_init(d, cfg.norm),
        "in_proj": layers.dense_init(ks[0], d, 2 * di + 2 * n + nh),
        "conv_w": jax.random.normal(ks[1], (cfg.conv_width, conv_ch),
                                    jnp.float32) * 0.1,
        "a_log": jnp.zeros((nh,), jnp.float32),          # A = -exp(a_log)
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_norm": layers.norm_init(di, "rmsnorm"),
        "out_proj": layers.dense_init(
            ks[2], di, d, scale=0.02 / np.sqrt(2 * cfg.num_layers)),
    }


def _mamba2_project(p, h, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    zxbcdt = matmul_any(h, p["in_proj"], dtype, impl=cfg.impl,
                        skip_activations=cfg.activation_skip)
    z, xc, b, c, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    return z, xc, b, c, dt, (di, n, nh)


def mamba2_apply(p, x: jax.Array, cfg: ModelConfig, *,
                 cache=None, chunk: int = 128):
    """cache = (conv_state [B,W-1,ch], ssm_state [B,H,p,n]) for decode."""
    dtype = jnp.dtype(cfg.dtype)
    bsz = x.shape[0]
    h = layers.apply_norm(p["ln"], x, cfg.norm)
    z, xc, b, c, dt, (di, n, nh) = _mamba2_project(p, h, cfg, dtype)
    hd = di // nh
    conv_in = jnp.concatenate([xc, b, c], axis=-1)
    conv_state = cache[0] if cache is not None else None
    conv_out, conv_state = causal_conv(conv_in, p["conv_w"], conv_state)
    xs, b, c = jnp.split(conv_out, [di, di + n], axis=-1)
    a = -jnp.exp(p["a_log"])                              # [H] negative
    log_a = (dt * a).astype(jnp.float32)                  # [B, L, H]
    u = (xs.reshape(bsz, -1, nh, hd).astype(jnp.float32)
         * dt[..., None])                                 # dt-scaled input
    bh = jnp.broadcast_to(b[:, :, None, :], (bsz, b.shape[1], nh, n))
    ch = jnp.broadcast_to(c[:, :, None, :], (bsz, c.shape[1], nh, n))
    if cache is None:
        y, h_final = ssd_chunked(u, bh, ch, log_a, chunk=min(
            chunk, u.shape[1]))
        new_cache = (conv_state, h_final)
    else:
        y1, h_final = ssd_step(u[:, 0], bh[:, 0], ch[:, 0], log_a[:, 0],
                               cache[1])
        y = y1[:, None]
        new_cache = (conv_state, h_final)
    y = y + xs.reshape(bsz, -1, nh, hd) * p["d_skip"][:, None]
    y = y.reshape(bsz, -1, di)
    y = layers.apply_norm(p["out_norm"], y, "rmsnorm") * jax.nn.silu(
        z.astype(jnp.float32)).astype(y.dtype)
    out = matmul_any(y, p["out_proj"], dtype, impl=cfg.impl,
                     skip_activations=cfg.activation_skip)
    return x + out, new_cache


def mamba2_cache_spec(cfg: ModelConfig, batch: int):
    di = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    return (
        jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, di + 2 * n),
                             jnp.dtype(cfg.dtype)),
        jax.ShapeDtypeStruct((batch, nh, di // nh, n), jnp.float32),
    )


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    nh = cfg.num_heads
    ks = jax.random.split(key, 6)
    return {
        "ln": layers.norm_init(d, cfg.norm),
        "up": layers.dense_init(ks[0], d, 2 * di),
        "wq": layers.dense_init(ks[1], di, di),
        "wk": layers.dense_init(ks[2], di, di),
        "wv": layers.dense_init(ks[3], di, di),
        "w_if": layers.dense_init(ks[4], di, 2 * nh, scale=0.01),
        "f_bias": jnp.full((nh,), 3.0, jnp.float32),   # open forget gates
        "out_norm": layers.norm_init(di, "rmsnorm"),
        "down": layers.dense_init(
            ks[5], di, d, scale=0.02 / np.sqrt(2 * cfg.num_layers)),
    }


def mlstm_apply(p, x: jax.Array, cfg: ModelConfig, *, cache=None,
                chunk: int = 128):
    """cache = state [B, H, hd+1, hd] (value augmented with normalizer row)."""
    dtype = jnp.dtype(cfg.dtype)
    bsz, l = x.shape[:2]
    d = cfg.d_model
    di = cfg.ssm_expand * d
    nh = cfg.num_heads
    hd = di // nh
    h = layers.apply_norm(p["ln"], x, cfg.norm)
    skip = cfg.activation_skip
    u2 = matmul_any(h, p["up"], dtype, impl=cfg.impl, skip_activations=skip)
    xm, z = jnp.split(u2, 2, axis=-1)
    impl = cfg.impl
    q = matmul_any(xm, p["wq"], dtype, impl=impl,
                   skip_activations=skip).reshape(bsz, l, nh,
                                                  hd) / np.sqrt(hd)
    k = matmul_any(xm, p["wk"], dtype, impl=impl,
                   skip_activations=skip).reshape(bsz, l, nh,
                                                  hd) / np.sqrt(hd)
    v = matmul_any(xm, p["wv"], dtype, impl=impl,
                   skip_activations=skip).reshape(bsz, l, nh, hd)
    gif = matmul_any(xm, p["w_if"], jnp.float32, impl=impl,
                     skip_activations=skip)
    ig, fg = jnp.split(gif, 2, axis=-1)                    # [B, L, H]
    log_a = jax.nn.log_sigmoid(fg + p["f_bias"])
    i_lin = jnp.exp(jnp.clip(ig, -10.0, 10.0))
    # augment v with a ones column: state carries (C | n) jointly
    v_aug = jnp.concatenate(
        [v.astype(jnp.float32) * i_lin[..., None],
         i_lin[..., None] * jnp.ones((bsz, l, nh, 1), jnp.float32)], axis=-1)
    if cache is None:
        y_aug, h_final = ssd_chunked(v_aug, k, q, log_a,
                                     chunk=min(chunk, l))
    else:
        y1, h_final = ssd_step(v_aug[:, 0], k[:, 0], q[:, 0], log_a[:, 0],
                               cache)
        y_aug = y1[:, None]
    y_num, y_den = y_aug[..., :hd], y_aug[..., hd:]
    y = y_num / jnp.maximum(jnp.abs(y_den), 1.0)
    y = y.reshape(bsz, -1, di).astype(dtype)
    y = layers.apply_norm(p["out_norm"], y, "rmsnorm") * jax.nn.silu(
        z.astype(jnp.float32)).astype(dtype)
    out = matmul_any(y, p["down"], dtype, impl=impl, skip_activations=skip)
    return x + out, h_final


def mlstm_cache_spec(cfg: ModelConfig, batch: int):
    di = cfg.ssm_expand * cfg.d_model
    hd = di // cfg.num_heads
    return jax.ShapeDtypeStruct((batch, cfg.num_heads, hd + 1, hd),
                                jnp.float32)


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — sequential scalar-memory recurrence
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    ks = jax.random.split(key, 4)
    return {
        "ln": layers.norm_init(d, cfg.norm),
        "w_in": layers.dense_init(ks[0], d, 4 * d),        # z i f o
        "r": jax.random.normal(ks[1], (nh, hd, 4 * hd), jnp.float32) * 0.02,
        "f_bias": jnp.full((d,), 3.0, jnp.float32),
        "out_norm": layers.norm_init(d, "rmsnorm"),
        "w_out": layers.dense_init(
            ks[2], d, d, scale=0.02 / np.sqrt(2 * cfg.num_layers)),
    }


def _slstm_cell(p, xt, state, cfg: ModelConfig):
    """xt [B, 4d] pre-proj; state = (c, n, h) each [B, d]."""
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    c_s, n_s, h_s = state
    rec = jnp.einsum("bnh,nhk->bnk", h_s.reshape(-1, nh, hd), p["r"])
    gates = xt + rec.reshape(-1, 4 * d)
    z, i, f, o = jnp.split(gates, 4, axis=-1)
    z = jnp.tanh(z)
    i = jnp.exp(jnp.clip(i, -10.0, 10.0))
    f = jax.nn.sigmoid(f + p["f_bias"])
    o = jax.nn.sigmoid(o)
    c_new = f * c_s + i * z
    n_new = f * n_s + i
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return (c_new, n_new, h_new)


def slstm_apply(p, x: jax.Array, cfg: ModelConfig, *, cache=None):
    """cache = (c, n, h) each [B, d] f32."""
    dtype = jnp.dtype(cfg.dtype)
    bsz, l, d = x.shape
    h0 = layers.apply_norm(p["ln"], x, cfg.norm)
    xt = matmul_any(h0, p["w_in"], jnp.float32, impl=cfg.impl,
                    skip_activations=cfg.activation_skip)   # [B, L, 4d]
    if cache is None:
        state = tuple(jnp.zeros((bsz, d), jnp.float32) for _ in range(3))
    else:
        state = cache

    def step(st, xt_t):
        st2 = _slstm_cell(p, xt_t, st, cfg)
        return st2, st2[2]

    if l == 1:
        state = _slstm_cell(p, xt[:, 0], state, cfg)
        ys = state[2][:, None]
    else:
        state, ys = jax.lax.scan(step, state, jnp.moveaxis(xt, 1, 0))
        ys = jnp.moveaxis(ys, 0, 1)
    y = layers.apply_norm(p["out_norm"], ys.astype(dtype), "rmsnorm")
    out = matmul_any(y, p["w_out"], dtype, impl=cfg.impl,
                     skip_activations=cfg.activation_skip)
    return x + out, state


def slstm_cache_spec(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return tuple(jax.ShapeDtypeStruct((batch, d), jnp.float32)
                 for _ in range(3))
