"""Shared neural-net layers: norms, linears (float or kneaded), RoPE,
activations, and attention in four execution regimes:

  * full    — materialized scores, small sequences (smoke tests, cross-attn)
  * masked  — blockwise online-softmax, causal blocks masked but computed
              (the naive baseline; 2x causal FLOP waste, kept for §Perf)
  * flash   — pair-list blockwise attention with custom_vjp: exact causal
              FLOPs, O(S) memory (the production path)
  * decode  — one query step against a KV cache

All weights are stored f32 and cast to the compute dtype at use.  Any linear
weight leaf may be replaced by a `QuantizedTensor` / `KneadedWeight` /
`PackedInt4` for the Tetris serving path — `matmul_any` dispatches.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kneading import KneadedWeight, ShardedKneadedWeight
from repro.core.quantization import QuantizedTensor

# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, scale: float = 0.02) -> jax.Array:
    return (jax.random.truncated_normal(key, -2, 2, (d_in, d_out), jnp.float32)
            * scale)


# ---------------------------------------------------------------------------
# Quantized weight container for the int4 serving mode
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedInt4:
    """Nibble-packed int4 weight [K/2, N] + per-channel scale (serving)."""

    packed: jax.Array
    scale: jax.Array
    k: int = dataclasses.field(metadata=dict(static=True), default=0)


def matmul_any(x: jax.Array, w, compute_dtype=jnp.bfloat16,
               impl: str = "int", skip_activations: bool = False) -> jax.Array:
    """x @ w for float, QuantizedTensor (int8), KneadedWeight, or PackedInt4.

    Quantized paths follow SAC: integer-code contraction with the per-channel
    scale applied once in the epilogue (never dequantize weights up front in
    a separate HBM-visible buffer).  ``impl`` selects the SAC execution path
    for KneadedWeight leaves ("float"/"int"/"planes"/"pallas"); float leaves
    ignore it.  N-sharded kneaded leaves (per-layer scan slices of a
    ``ShardedStackedKneadedWeight``, or plain ``ShardedKneadedWeight``)
    dispatch through the sharded Pallas entry under the serving mesh
    (docs/DESIGN.md §8).  ``skip_activations`` arms the runtime two-sided
    skip on kneaded leaves (``cfg.activation_skip``; docs/DESIGN.md §12) —
    decode-GEMV calls only, bit-exact on/off, ignored by every other leaf
    type.
    """
    if isinstance(w, (KneadedWeight, ShardedKneadedWeight)):
        from repro.core.sac import sac_matmul
        return sac_matmul(x, w, impl=impl,
                          skip_activations=skip_activations
                          ).astype(compute_dtype)
    if isinstance(w, QuantizedTensor):
        out = jnp.einsum("...k,kn->...n", x.astype(compute_dtype),
                         w.q.astype(compute_dtype),
                         preferred_element_type=jnp.float32)
        return (out * w.scale).astype(compute_dtype)
    if isinstance(w, PackedInt4):
        from repro.kernels.kneaded_gemm.ref import unpack_int4
        q = unpack_int4(w.packed)
        out = jnp.einsum("...k,kn->...n", x.astype(compute_dtype),
                         q.astype(compute_dtype),
                         preferred_element_type=jnp.float32)
        return (out * w.scale).astype(compute_dtype)
    # preferred_element_type == compute dtype, NOT the jnp default (f32):
    # with the contraction dim sharded, SPMD all-reduces the dot's partial
    # sums — at f32 that is 2x the bytes of every TP collective (measured:
    # the top-5 collectives on llama3 train were f32 activation reductions).
    # The MXU still accumulates f32 within a shard; only the cross-shard
    # combine is bf16 (standard tensor-parallel practice).
    return jnp.einsum("...k,kn->...n", x.astype(compute_dtype),
                      w.astype(compute_dtype),
                      preferred_element_type=compute_dtype)


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(p, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * p["scale"]).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * p["scale"] + p["bias"]).astype(x.dtype)


def rms_head_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMSNorm over the head dim (qwen3 qk-norm)."""
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale).astype(x.dtype)


def activate(x: jax.Array, kind: str) -> jax.Array:
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":                      # nemotron squared-ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, ..., hd]; positions: [B, S] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq     # [B, S, half]
    # broadcast over head axes between S and hd
    extra = x.ndim - 3
    ang = ang.reshape(ang.shape[:2] + (1,) * extra + (half,))
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA layout: q [B,S,KV,G,hd], k/v [B,S,KV,hd])
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def quantize_kv(x: jax.Array):
    """int8-quantize a KV tensor [..., hd] with per-row (pos, head) scales.

    The paper's "fewer effective bits" applied to the decode-dominant byte
    stream: the KV cache.  Returns (codes int8 [..., hd], scale f32 [...])."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return codes, scale


def _scores(q, k, scale):
    # q: [B,Sq,KV,G,hd], k: [B,Sk,KV,hd] -> [B,KV,G,Sq,Sk]
    return jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                      k.astype(jnp.float32)) * scale


def full_attention(q, k, v, *, causal: bool, window: int = 0,
                   kv_offset: int = 0) -> jax.Array:
    """Reference attention, materializes scores (small S only)."""
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    s = _scores(q, k, 1.0 / np.sqrt(hd))
    qpos = kv_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _chunk_pairs(nq: int, nk: int, causal: bool, window_chunks: int):
    """Static (qi, ki) chunk-pair list for exact-FLOP blockwise attention."""
    pairs = []
    for qi in range(nq):
        lo = 0 if not window_chunks else max(0, qi - window_chunks)
        hi = (qi + 1) if causal else nk
        for ki in range(lo, hi):
            pairs.append((qi, ki))
    return np.array(pairs, np.int32)


def _block_attend(qc, kc, vc, qi, ki, chunk, causal, window, scale):
    """One chunk pair -> (m, l, o) partials.  qc: [B,cq,KV,G,hd]."""
    s = _scores(qc, kc, scale)                               # [B,KV,G,cq,ck]
    qpos = qi * chunk + jnp.arange(qc.shape[1])[:, None]
    kpos = ki * chunk + jnp.arange(kc.shape[1])[None, :]
    mask = jnp.ones(s.shape[-2:], bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # [B,KV,G,cq]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", p, vc.astype(jnp.float32))
    return m, l, o


def chunked_attention(q, k, v, *, causal: bool, chunk: int, window: int = 0,
                      exact: bool = True) -> jax.Array:
    """Blockwise online-softmax attention.

    exact=True  : scan over the lower-triangle chunk-pair list only
                  (HLO FLOPs == true causal FLOPs).
    exact=False : scan over the full chunk grid with masking (baseline).
    """
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    assert sq % chunk == 0 and sk % chunk == 0, (sq, sk, chunk)
    nq, nk = sq // chunk, sk // chunk
    scale = 1.0 / np.sqrt(hd)
    wc = (window + chunk - 1) // chunk if window else 0

    qch = q.reshape(b, nq, chunk, kvh, g, hd)
    kch = k.reshape(b, nk, chunk, kvh, hd)
    vch = v.reshape(b, nk, chunk, kvh, hd)

    if exact:
        pairs = _chunk_pairs(nq, nk, causal, wc)
        # carry: running (m, l, o) for every q chunk; one dynamic-slice update
        # per visited pair.  FLOPs = exactly the unmasked pair count.
        m0 = jnp.full((nq, b, kvh, g, chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((nq, b, kvh, g, chunk), jnp.float32)
        o0 = jnp.zeros((nq, b, kvh, g, chunk, hd), jnp.float32)

        def step(carry, pair):
            m_all, l_all, o_all = carry
            qi, ki = pair[0], pair[1]
            qc = jax.lax.dynamic_index_in_dim(qch, qi, 1, keepdims=False)
            kc = jax.lax.dynamic_index_in_dim(kch, ki, 1, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vch, ki, 1, keepdims=False)
            mb, lb, ob = _block_attend(qc, kc, vc, qi, ki, chunk, causal,
                                       window, scale)
            m_old = m_all[qi]
            l_old = l_all[qi]
            o_old = o_all[qi]
            m_new = jnp.maximum(m_old, mb)
            c_old = jnp.exp(m_old - m_new)
            c_blk = jnp.exp(mb - m_new)
            l_new = l_old * c_old + lb * c_blk
            o_new = o_old * c_old[..., None] + ob * c_blk[..., None]
            return ((m_all.at[qi].set(m_new), l_all.at[qi].set(l_new),
                     o_all.at[qi].set(o_new)), None)

        (m_all, l_all, o_all), _ = jax.lax.scan(step, (m0, l0, o0),
                                                jnp.asarray(pairs))
        out = o_all / jnp.maximum(l_all[..., None], 1e-30)    # [nq,B,KV,G,c,hd]
        out = jnp.transpose(out, (1, 0, 4, 2, 3, 5))          # [B,nq,c,KV,G,hd]
        return out.reshape(b, sq, kvh, g, hd).astype(q.dtype)

    # --- masked baseline: every (qi, ki) pair computed, causal blocks masked
    def per_q_chunk(args):
        qi, qc = args

        def kv_step(carry, args2):
            ki, kc, vc = args2
            m_old, l_old, o_old = carry
            mb, lb, ob = _block_attend(qc, kc, vc, qi, ki, chunk, causal,
                                       window, scale)
            m_new = jnp.maximum(m_old, mb)
            c_old = jnp.exp(m_old - m_new)
            c_blk = jnp.exp(mb - m_new)
            return (m_new, l_old * c_old + lb * c_blk,
                    o_old * c_old[..., None] + ob * c_blk[..., None]), None

        init = (jnp.full((b, kvh, g, chunk), NEG_INF, jnp.float32),
                jnp.zeros((b, kvh, g, chunk), jnp.float32),
                jnp.zeros((b, kvh, g, chunk, hd), jnp.float32))
        (m, l, o), _ = jax.lax.scan(
            kv_step, init,
            (jnp.arange(nk), jnp.moveaxis(kch, 1, 0), jnp.moveaxis(vch, 1, 0)))
        return o / jnp.maximum(l[..., None], 1e-30)

    out = jax.lax.map(per_q_chunk, (jnp.arange(nq), jnp.moveaxis(qch, 1, 0)))
    # out: [nq, B, KV, G, c, hd] -> [B, S, KV, G, hd]
    out = jnp.transpose(out, (1, 0, 4, 2, 3, 5))
    return out.reshape(b, sq, kvh, g, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos: jax.Array,
                     window: int = 0) -> jax.Array:
    """One-step attention: q [B,1,KV,G,hd] vs cache [B,Smax,KV,hd].

    ``pos`` [B] is the index of the *current* token (cache valid < pos+1).
    """
    b, _, kvh, g, hd = q.shape
    smax = k_cache.shape[1]
    s = _scores(q, k_cache, 1.0 / np.sqrt(hd))                # [B,KV,G,1,Smax]
    kpos = jnp.arange(smax)[None, :]
    valid = kpos <= pos[:, None]
    if window:
        valid &= kpos > pos[:, None] - window
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Flash attention (pair-list, custom_vjp): exact causal FLOPs, O(S) memory.
# The forward is the `exact` path above; the custom backward recomputes
# per-pair probabilities from (q, k, v, lse) — no online-softmax carries or
# block masks are ever saved (the failure mode of the masked baseline, see
# EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------

def _batch_only(x, batch_axis=1):
    """Pin a flash-loop tensor to batch-only sharding (heads replicated).

    For archs whose kv-head count does not divide the TP degree, GSPMD
    replicates attention heads; without pinning, the scan carries and chunk
    stacks pick inconsistent layouts and every pair step re-gathers its
    operands (measured: 5.8 TiB/device/step on nemotron train).  Pinning
    everything batch-only makes the replication explicit and one-time."""
    from repro.runtime import pspec
    spec = [None] * x.ndim
    spec[batch_axis] = "batch"
    return pspec.constrain(x, *spec)


def _flash_fwd_impl(q, k, v, causal, chunk, window, replicate_heads=False):
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    nq, nk = sq // chunk, sk // chunk
    scale = 1.0 / np.sqrt(hd)
    wc = (window + chunk - 1) // chunk if window else 0
    pairs = _chunk_pairs(nq, nk, causal, wc)
    qch = jnp.moveaxis(q.reshape(b, nq, chunk, kvh, g, hd), 1, 0)
    kch = jnp.moveaxis(k.reshape(b, nk, chunk, kvh, hd), 1, 0)
    vch = jnp.moveaxis(v.reshape(b, nk, chunk, kvh, hd), 1, 0)

    m0 = jnp.full((nq, b, kvh, g, chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, b, kvh, g, chunk), jnp.float32)
    o0 = jnp.zeros((nq, b, kvh, g, chunk, hd), jnp.float32)
    if replicate_heads:
        qch, kch, vch, m0, l0, o0 = (
            _batch_only(t) for t in (qch, kch, vch, m0, l0, o0))

    def step(carry, pair):
        m_all, l_all, o_all = carry
        qi, ki = pair[0], pair[1]
        mb, lb, ob = _block_attend(qch[qi], kch[ki], vch[ki], qi, ki, chunk,
                                   causal, window, scale)
        m_old, l_old, o_old = m_all[qi], l_all[qi], o_all[qi]
        m_new = jnp.maximum(m_old, mb)
        c_old = jnp.exp(m_old - m_new)
        c_blk = jnp.exp(mb - m_new)
        return ((m_all.at[qi].set(m_new),
                 l_all.at[qi].set(l_old * c_old + lb * c_blk),
                 o_all.at[qi].set(o_old * c_old[..., None]
                                  + ob * c_blk[..., None])), None)

    (m_all, l_all, o_all), _ = jax.lax.scan(step, (m0, l0, o0),
                                            jnp.asarray(pairs))
    lse = m_all + jnp.log(jnp.maximum(l_all, 1e-30))     # [nq,B,KV,G,c]
    out = o_all / jnp.maximum(l_all[..., None], 1e-30)
    out = jnp.transpose(out, (1, 0, 4, 2, 3, 5)).reshape(b, sq, kvh, g, hd)
    return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool, chunk: int, window: int,
                    replicate_heads: bool = False):
    out, _ = _flash_fwd_impl(q, k, v, causal, chunk, window, replicate_heads)
    return out


def _flash_vjp_fwd(q, k, v, causal, chunk, window, replicate_heads):
    out, lse = _flash_fwd_impl(q, k, v, causal, chunk, window,
                               replicate_heads)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, chunk, window, replicate_heads, res, do):
    q, k, v, out, lse = res
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    nq, nk = sq // chunk, sk // chunk
    scale = 1.0 / np.sqrt(hd)
    wc = (window + chunk - 1) // chunk if window else 0
    pairs = _chunk_pairs(nq, nk, causal, wc)

    f32 = jnp.float32
    qch = jnp.moveaxis(q.reshape(b, nq, chunk, kvh, g, hd), 1, 0).astype(f32)
    kch = jnp.moveaxis(k.reshape(b, nk, chunk, kvh, hd), 1, 0).astype(f32)
    vch = jnp.moveaxis(v.reshape(b, nk, chunk, kvh, hd), 1, 0).astype(f32)
    doch = jnp.moveaxis(do.reshape(b, nq, chunk, kvh, g, hd), 1, 0).astype(f32)
    # delta[i] = rowsum(do * out)
    delta = jnp.sum(do.astype(f32) * out.astype(f32), axis=-1)  # [B,S,KV,G]
    delta = jnp.moveaxis(
        delta.reshape(b, nq, chunk, kvh, g), 1, 0)              # [nq,B,c,KV,G]
    # lse from fwd: [nq,B,KV,G,c] -> match [nq,B,c,KV,G]
    lse_t = jnp.transpose(lse, (0, 1, 4, 2, 3))

    dq0 = jnp.zeros((nq, b, chunk, kvh, g, hd), f32)
    dk0 = jnp.zeros((nk, b, chunk, kvh, hd), f32)
    dv0 = jnp.zeros((nk, b, chunk, kvh, hd), f32)
    if replicate_heads:
        qch, kch, vch, doch, delta, lse_t, dq0, dk0, dv0 = (
            _batch_only(t) for t in (qch, kch, vch, doch, delta, lse_t,
                                     dq0, dk0, dv0))

    def step(carry, pair):
        dq_all, dk_all, dv_all = carry
        qi, ki = pair[0], pair[1]
        qc, kc, vc, doc = qch[qi], kch[ki], vch[ki], doch[qi]
        s = jnp.einsum("bqkgh,bskh->bkgqs", qc, kc) * scale
        qpos = qi * chunk + jnp.arange(chunk)[:, None]
        kpos = ki * chunk + jnp.arange(chunk)[None, :]
        mask = jnp.ones((chunk, chunk), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        # p = exp(s - lse): true softmax probabilities of this block
        p = jnp.exp(s - jnp.transpose(lse_t[qi], (0, 2, 3, 1))[..., None])
        dv_blk = jnp.einsum("bkgqs,bqkgh->bskh", p, doc)
        dp = jnp.einsum("bqkgh,bskh->bkgqs", doc, vc)
        dlt = jnp.transpose(delta[qi], (0, 2, 3, 1))[..., None]  # [B,KV,G,c,1]
        ds = p * (dp - dlt) * scale
        dq_blk = jnp.einsum("bkgqs,bskh->bqkgh", ds, kc)
        dk_blk = jnp.einsum("bkgqs,bqkgh->bskh", ds, qc)
        return ((dq_all.at[qi].add(dq_blk),
                 dk_all.at[ki].add(dk_blk),
                 dv_all.at[ki].add(dv_blk)), None)

    (dq_all, dk_all, dv_all), _ = jax.lax.scan(step, (dq0, dk0, dv0),
                                               jnp.asarray(pairs))
    dq = jnp.moveaxis(dq_all, 0, 1).reshape(b, sq, kvh, g, hd).astype(q.dtype)
    dk = jnp.moveaxis(dk_all, 0, 1).reshape(b, sk, kvh, hd).astype(k.dtype)
    dv = jnp.moveaxis(dv_all, 0, 1).reshape(b, sk, kvh, hd).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def attend(q, k, v, *, causal: bool, impl: str, chunk: int,
           window: int = 0, replicate_heads: bool = False) -> jax.Array:
    """Dispatch on sequence length / implementation choice.

    impl="flash"  : pair-list exact-FLOP blockwise attention w/ custom vjp
    impl="masked" : chunked online-softmax, every block computed+masked
                    (the naive baseline; kept for §Perf comparisons)
    """
    sq, sk = q.shape[1], k.shape[1]
    if max(sq, sk) <= max(chunk, 512) or sq % chunk or sk % chunk:
        return full_attention(q, k, v, causal=causal, window=window)
    if impl == "flash":
        return flash_attention(q, k, v, causal, chunk, window,
                               replicate_heads)
    return chunked_attention(q, k, v, causal=causal, chunk=chunk,
                             window=window, exact=False)
