"""CNNs from the paper's own evaluation set (AlexNet / VGG-16 / NiN style).

Convolution is implemented as im2col -> matmul so every conv layer is a
[K = C*kh*kw, N = out_ch] weight *matrix* — exactly the form weight kneading
and SAC consume (the paper's accelerator likewise lowers conv to weight/
activation lanes).  These models drive the paper-reproduction benchmarks
(Table 1, Figs 2/8/9/10/11) and run fully kneaded on the serving path:
``knead_params`` converts every conv/fc kernel to :class:`KneadedWeight`
(conv via its im2col matrix, zero-padded to tile alignment) and ``apply``
takes an ``impl`` selector ("float" | "int" | "planes" | "pallas") that
routes every layer's matmul through the chosen SAC execution path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kneading import (KneadedWeight, ShardedKneadedWeight,
                                 knead_padded, shard_schedule)
# the single conv-lowering definition, shared with sac_conv2d so float and
# kneaded convolutions see identical patch layouts
from repro.kernels.sac_matmul.ops import im2col as _im2col
from repro.models import layers as L

# spec entries: ("conv", out_ch, k, stride) | ("pool", k) | ("fc", out)
CNNSpec = Sequence[Tuple]


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    spec: CNNSpec
    in_channels: int = 3
    image_size: int = 32       # scaled-down inputs for CPU feasibility
    num_classes: int = 100


ALEXNET = CNNConfig("alexnet", (
    ("conv", 64, 3, 1), ("pool", 2),
    ("conv", 192, 3, 1), ("pool", 2),
    ("conv", 384, 3, 1), ("conv", 256, 3, 1), ("conv", 256, 3, 1),
    ("pool", 2),
    ("fc", 1024), ("fc", 1024), ("fc", 100),
))

VGG16 = CNNConfig("vgg16", (
    ("conv", 64, 3, 1), ("conv", 64, 3, 1), ("pool", 2),
    ("conv", 128, 3, 1), ("conv", 128, 3, 1), ("pool", 2),
    ("conv", 256, 3, 1), ("conv", 256, 3, 1), ("conv", 256, 3, 1), ("pool", 2),
    ("conv", 512, 3, 1), ("conv", 512, 3, 1), ("conv", 512, 3, 1), ("pool", 2),
    ("conv", 512, 3, 1), ("conv", 512, 3, 1), ("conv", 512, 3, 1), ("pool", 2),
    ("fc", 1024), ("fc", 1024), ("fc", 100),
))

NIN = CNNConfig("nin", (
    ("conv", 192, 5, 1), ("conv", 160, 1, 1), ("conv", 96, 1, 1), ("pool", 2),
    ("conv", 192, 5, 1), ("conv", 192, 1, 1), ("conv", 192, 1, 1), ("pool", 2),
    ("conv", 192, 3, 1), ("conv", 192, 1, 1), ("conv", 100, 1, 1),
))

CNN_ZOO = {c.name: c for c in (ALEXNET, VGG16, NIN)}




def init(key, cfg: CNNConfig) -> Dict:
    params: Dict = {}
    c = cfg.in_channels
    size = cfg.image_size
    keys = jax.random.split(key, len(cfg.spec))
    flat = None
    for i, item in enumerate(cfg.spec):
        kind = item[0]
        if kind == "conv":
            _, out_c, k, stride = item
            params[f"conv{i}"] = {
                "w": L.dense_init(keys[i], c * k * k, out_c,
                                  scale=float(np.sqrt(2.0 / (c * k * k)))),
                "b": jnp.zeros((out_c,), jnp.float32),
            }
            c = out_c
            size //= stride
        elif kind == "pool":
            size //= item[1]
        elif kind == "fc":
            _, out = item
            d_in = flat if flat is not None else c * size * size
            params[f"fc{i}"] = {
                "w": L.dense_init(keys[i], d_in, out,
                                  scale=float(np.sqrt(2.0 / d_in))),
                "b": jnp.zeros((out,), jnp.float32),
            }
            flat = out
    return params


def apply(params: Dict, x: jax.Array, cfg: CNNConfig,
          collect_activations: bool = False, impl: str = "float",
          mesh=None, shard_axis: str = "model",
          skip_activations: bool = False):
    """x [B, H, W, C] -> logits [B, classes] (+ per-layer matmul inputs).

    ``impl`` selects the execution path for kneaded layers (see module
    docstring); "float" runs plain f32 matmuls on float weights.  Kneaded
    conv layers go through :func:`repro.kernels.sac_matmul.ops.sac_conv2d`
    — im2col + schedule-compacted SAC matmul, one ``pallas_call`` per layer
    with all activation rows streamed through the kernel grid's M dimension.
    ``ShardedKneadedWeight`` layers (see :func:`shard_kneaded_params`) run
    one kernel launch per ``mesh`` device over ``shard_axis``, each walking
    its own shard's compacted work list; ``mesh=None`` executes the shards
    serially (the single-device oracle).
    """
    acts: Dict[str, jax.Array] = {}
    flat = False
    for i, item in enumerate(cfg.spec):
        kind = item[0]
        if kind == "conv":
            _, out_c, k, stride = item
            p = params[f"conv{i}"]
            if isinstance(p["w"], (KneadedWeight, ShardedKneadedWeight)):
                from repro.kernels.sac_matmul.ops import sac_conv2d
                if collect_activations:
                    patches = _im2col(x, k, stride)
                    acts[f"conv{i}"] = patches.reshape(-1, patches.shape[-1])
                x = sac_conv2d(x, p["w"], ksize=k, stride=stride, bias=p["b"],
                               impl=impl, mesh=mesh, axis=shard_axis)
            else:
                patches = _im2col(x, k, stride)
                if collect_activations:
                    acts[f"conv{i}"] = patches.reshape(-1, patches.shape[-1])
                x = L.matmul_any(patches, p["w"], jnp.float32,
                                 impl=impl) + p["b"]
            x = jax.nn.relu(x)
        elif kind == "pool":
            k = item[1]
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")
        elif kind == "fc":
            if not flat:
                x = x.reshape(x.shape[0], -1)
                flat = True
            if collect_activations:
                acts[f"fc{i}"] = x
            p = params[f"fc{i}"]
            if isinstance(p["w"], ShardedKneadedWeight):
                from repro.kernels.sac_matmul.ops import sac_matmul_pallas_sharded
                out = sac_matmul_pallas_sharded(
                    x, p["w"], mesh, shard_axis,
                    skip_activations=skip_activations and x.shape[0] <= 8)
                x = out[:, :p["w"].logical_n] + p["b"]
            else:
                x = L.matmul_any(x, p["w"], jnp.float32, impl=impl,
                                 skip_activations=skip_activations) + p["b"]
            if i != len(cfg.spec) - 1:
                x = jax.nn.relu(x)
    if x.ndim == 4:                 # NiN: global average pooling head
        x = jnp.mean(x, axis=(1, 2))
    return (x, acts) if collect_activations else x


def knead_params(params: Dict, bits: int = 8, ks: int = 256,
                 n_block: int = 128) -> Dict:
    """Convert every conv/fc kernel of a float checkpoint to KneadedWeight.

    Conv layers knead their im2col [C*kh*kw, out_ch] matrices; arbitrary
    reduction dims are zero-padded to the lcm(32, ks) / n_block alignment
    (exact — padding has occupancy 0 and is skipped by the kernel).  Biases
    stay float: the paper kneads the weight stream only.
    """
    out: Dict = {}
    for name, p in params.items():
        out[name] = {"w": knead_padded(p["w"], bits=bits, ks=ks,
                                       n_block=n_block),
                     "b": p["b"]}
    return out


def shard_kneaded_params(kparams: Dict, mesh, axis: str = "model",
                         partition: str = "contiguous") -> Dict:
    """Partition every KneadedWeight of a kneaded checkpoint along N.

    Each layer's compacted schedule splits into per-device work lists
    (:func:`repro.core.schedule.shard_schedule`); biases stay whole
    (replicated — every device's epilogue adds its output-column slice).
    ``partition="balanced"`` LPT-packs each layer's tiles on static
    occupancy instead of contiguous slabs (docs/DESIGN.md §11).  Place the
    result with ``runtime.sharding.kneaded_shardings`` before serving.
    """
    return {name: {"w": shard_schedule(p["w"], mesh, axis=axis,
                                       partition=partition),
                   "b": p["b"]}
            for name, p in kparams.items()}


def weight_matrices(params: Dict) -> Dict[str, jax.Array]:
    """Every layer as its [K, N] matmul matrix (the kneading target)."""
    return {name: p["w"] for name, p in params.items()}


def train_briefly(key, cfg: CNNConfig, steps: int = 30, batch: int = 32,
                  lr: float = 1e-2) -> Dict:
    """A few SGD steps on a synthetic-but-learnable task, so weight
    statistics resemble trained (leptokurtic) weights rather than the init
    Gaussian — the paper measures *trained* Caffe models."""
    params = init(key, cfg)
    kdata = jax.random.split(key, steps)

    def loss_fn(p, x, y):
        logits = apply(p, x, cfg)
        return jnp.mean(
            -jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y])

    @jax.jit
    def step(p, k):
        x = jax.random.normal(k, (batch, cfg.image_size, cfg.image_size,
                                  cfg.in_channels))
        # learnable rule: class = argmax of channel-mean patches
        y = jnp.argmax(jnp.mean(x, axis=(1, 2)), axis=-1) % cfg.num_classes
        g = jax.grad(loss_fn)(p, x, y)
        return jax.tree.map(lambda w, gw: w - lr * gw, p, g)

    for k in kdata:
        params = step(params, k)
    return params
