"""Occupancy-compacted work schedules for the SAC Pallas kernel.

The occupancy map already knows, at knead time, exactly which
(plane, K-tile, N-tile) blocks carry essential bits.  The dense-grid kernel
still *visited* every block and predicated the dot (``pl.when(occ > 0)``) —
every slack block cost a grid step, an unpack, and a branch.  This module
turns the metadata into a *schedule* instead: per N-tile, a compacted list of
the non-empty ``(plane, k_tile)`` work items, so the kernel grid walks real
work only and executed MXU passes equal the occupancy nonzero count, not
``(B-1) * K/bk * N/bn``.  This is the TPU realization of front-end
ineffectual-work scheduling (Bit-Tactical) + essential-bit-only execution
(Laconic): slack is never dispatched, rather than dispatched-and-skipped.

Work order is **k-major** (k_tile ascending, plane ascending within a
k_tile): consecutive items then share the activation K-block and the sign
block, so the kernel's index maps re-request the same blocks and Pallas
elides the re-fetch.  Within a fixed plane, k_tiles therefore ascend — the
same per-segment accumulation order as a dense K sweep, which is what keeps
the compacted kernel bit-exact against the planes oracle.

Ragged tiles are padded to the max work count by *repeating the last real
item* (index maps of padded steps request already-resident blocks: no DMA),
and the kernel guards the dot with ``w < counts[j]``.  All-empty N-tiles
carry count 0 and execute nothing.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["KneadedSchedule", "build_schedule", "replay_schedule"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KneadedSchedule:
    """Compacted per-N-tile work lists for one kneaded weight.

    Attributes:
      counts:    int32 [N/n_block] — number of real work items per N-tile.
      plane_ids: int32 [N/n_block, num_work] — plane index of each item.
      ktile_ids: int32 [N/n_block, num_work] — K-tile index of each item.
                 Entries past ``counts[j]`` repeat the tile's last real item
                 (or 0 for all-empty tiles) so padded grid steps re-request
                 resident blocks.
      num_work:  static grid extent of the work dimension:
                 ``max(1, max(counts))`` (>= 1 so init/epilogue always run).
      total_work: static sum of counts == occupancy nonzero count == MXU
                 passes the kernel executes per M-step row of the grid.
      nk, n_tiles: static dense extents (K/ks, N/n_block) — the dense
                 schedule would be ``(B-1) * nk`` items per N-tile.
    """

    counts: jax.Array
    plane_ids: jax.Array
    ktile_ids: jax.Array
    num_work: int = dataclasses.field(metadata=dict(static=True), default=1)
    total_work: int = dataclasses.field(metadata=dict(static=True), default=0)
    nk: int = dataclasses.field(metadata=dict(static=True), default=0)
    n_tiles: int = dataclasses.field(metadata=dict(static=True), default=0)

    def dense_work(self, bits: int) -> int:
        """Work items the dense grid would execute: (B-1) * K/ks * N/n_block."""
        return (bits - 1) * self.nk * self.n_tiles

    def metadata_bytes(self) -> int:
        return (self.counts.size + self.plane_ids.size
                + self.ktile_ids.size) * 4


def build_schedule(occupancy_map: jax.Array) -> KneadedSchedule:
    """Flatten an occupancy presence map into a compacted schedule.

    Args:
      occupancy_map: {0,1} int array [B-1, K/ks, N/n_block] (the unpacked
        pass-mark metadata).  Host-side (numpy) — kneading is an offline
        conversion, and ``num_work``/``total_work`` must be static.
    Returns:
      A :class:`KneadedSchedule` whose items enumerate exactly the nonzero
      occupancy entries, k-major per N-tile.
    """
    occ = np.asarray(occupancy_map) != 0                   # [B-1, NK, NN]
    nb, nk, nn = occ.shape
    counts = occ.sum(axis=(0, 1)).astype(np.int32)         # [NN]
    num_work = max(1, int(counts.max(initial=0)))
    plane_ids = np.zeros((nn, num_work), np.int32)
    ktile_ids = np.zeros((nn, num_work), np.int32)
    for j in range(nn):
        # [NK, B-1] nonzero -> row-major: k_tile ascending, plane within
        kt, pb = np.nonzero(occ[:, :, j].T)
        c = kt.size
        if c:
            plane_ids[j, :c], ktile_ids[j, :c] = pb, kt
            plane_ids[j, c:], ktile_ids[j, c:] = pb[-1], kt[-1]
    return KneadedSchedule(
        counts=jnp.asarray(counts),
        plane_ids=jnp.asarray(plane_ids),
        ktile_ids=jnp.asarray(ktile_ids),
        num_work=num_work,
        total_work=int(counts.sum()),
        nk=nk,
        n_tiles=nn,
    )


def replay_schedule(a, kw) -> jax.Array:
    """Executable spec of the compacted kernel: walk the schedule on the host.

    Replays, in numpy, exactly the work items the kernel's grid executes —
    per N-tile, per work item ``w < counts[j]``, one f32 dot accumulated into
    that item's plane segment, then the rear-adder-tree epilogue.  Used by
    the schedule property tests as the order-faithful oracle; bit-exact
    against both ``impl="planes"`` and ``impl="pallas"``.

    Control flow (which items run, in what order) is host-side numpy over the
    schedule arrays; the arithmetic itself is the same jnp ops as the planes
    oracle, so accumulation rounding is identical operation-for-operation.

    Args:
      a:  [M, K] activations (K == kw.k, stored/padded dim).
      kw: a :class:`repro.core.kneading.KneadedWeight` with a schedule.
    """
    from repro.core import bitplanes

    sched = kw.schedule
    mag = bitplanes.unpack_bits(kw.planes, axis=1)               # [B-1, K, N]
    sign = 1 - 2 * bitplanes.unpack_bits(kw.signs, axis=0).astype(jnp.int8)
    a32 = jnp.asarray(a, jnp.float32)
    counts = np.asarray(sched.counts)
    plane_ids = np.asarray(sched.plane_ids)
    ktile_ids = np.asarray(sched.ktile_ids)
    ks, nb = kw.ks, kw.n_block
    m = a32.shape[0]
    weights = (2.0 ** jnp.arange(kw.bits - 1)).reshape(-1, 1, 1)
    out_tiles = []
    for j in range(sched.n_tiles):
        nsl = slice(j * nb, (j + 1) * nb)
        seg = [jnp.zeros((m, nb), jnp.float32) for _ in range(kw.bits - 1)]
        for w in range(int(counts[j])):                # real items only
            b, t = int(plane_ids[j, w]), int(ktile_ids[j, w])
            ksl = slice(t * ks, (t + 1) * ks)
            plane = (mag[b, ksl, nsl].astype(jnp.int8)
                     * sign[ksl, nsl]).astype(jnp.float32)
            seg[b] = seg[b] + a32[:, ksl] @ plane      # S_b += A_t @ P_bt
        out_tiles.append(jnp.sum(jnp.stack(seg) * weights, axis=0))
    out = jnp.concatenate(out_tiles, axis=1)
    return out * kw.scale
