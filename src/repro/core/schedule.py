"""Occupancy-compacted work schedules for the SAC Pallas kernel.

The occupancy map already knows, at knead time, exactly which
(plane, K-tile, N-tile) blocks carry essential bits.  The dense-grid kernel
still *visited* every block and predicated the dot (``pl.when(occ > 0)``) —
every slack block cost a grid step, an unpack, and a branch.  This module
turns the metadata into a *schedule* instead: per N-tile, a compacted list of
the non-empty ``(plane, k_tile)`` work items, so the kernel grid walks real
work only and executed MXU passes equal the occupancy nonzero count, not
``(B-1) * K/bk * N/bn``.  This is the TPU realization of front-end
ineffectual-work scheduling (Bit-Tactical) + essential-bit-only execution
(Laconic): slack is never dispatched, rather than dispatched-and-skipped.

Work order is **k-major** (k_tile ascending, plane ascending within a
k_tile): consecutive items then share the activation K-block and the sign
block, so the kernel's index maps re-request the same blocks and Pallas
elides the re-fetch.  Within a fixed plane, k_tiles therefore ascend — the
same per-segment accumulation order as a dense K sweep, which is what keeps
the compacted kernel bit-exact against the planes oracle.

Ragged tiles are padded to the max work count by *repeating the last real
item* (index maps of padded steps request already-resident blocks: no DMA),
and the kernel guards the dot with ``w < counts[j]``.  All-empty N-tiles
carry count 0 and execute nothing.

**Sharding** (:func:`shard_schedule`, docs/DESIGN.md §5): because the work
lists are independent per N-tile, the schedule partitions along N for free —
each shard of a device mesh takes a contiguous slab of N-tiles together with
exactly those tiles' work lists.  The per-tile items and their k-major order
are untouched, so a shard computes its output columns through the *same*
accumulation sequence as the single-device kernel and sharded execution
stays bit-exact.  Load per device is its shard's *occupancy* (sum of its
tiles' counts), not its dense tile count — the SCNN/Bit-Tactical principle
of distributing the compacted work list rather than the dense iteration
space — and :meth:`ShardedKneadedWeight.imbalance` reports how unevenly the
occupancy landed.

**Balanced sharding** (``partition="balanced"``, docs/DESIGN.md §11):
contiguous slabs inherit whatever skew the occupancy happens to carry — a
column-pruned layer can land all of its work on shard 0 while shard 3 idles.
Because per-tile work is static, the partitioner can do better at shard
time: LPT greedy bin-packing assigns tiles (largest count first) to the
least-loaded shard with free capacity, and the tile→slot permutation is
recorded in ``tile_slot`` so the execution layer can gather the output
columns back into original order.  Per-tile work lists and their k-major
order are untouched — only *which shard runs which tile* changes — so the
per-tile f32 accumulation sequence, and therefore the output bits, are
identical to the contiguous and unsharded kernels.  All-empty padding tiles
participate in the packing as zero-cost filler, so indivisible tile counts
never inflate ``shard_work`` (contiguous mode pins them to the last shard).

**Stacked sharding** (:func:`shard_stacked_schedule`, docs/DESIGN.md §8):
the LM stacks scan-layer weights as [L, K, N] with per-layer schedules
(``knead_stacked``); sharding applies the same N partition to every layer,
producing a :class:`ShardedStackedKneadedWeight` whose arrays carry
``[L, S, ...]`` axes — layer outermost so ``jax.lax.scan`` slices out each
layer's per-shard slabs, shard axis next for mesh placement.  Per-layer,
per-shard work totals are static (``layer_shard_work``) so load reports
need no device round-trips.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import TYPE_CHECKING, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # avoid the import cycle (kneading imports this module)
    from repro.core.kneading import KneadedWeight

__all__ = ["KneadedIntegrityError", "KneadedSchedule", "ShardedKneadedWeight",
           "ShardedStackedKneadedWeight", "build_schedule", "replay_schedule",
           "shard_schedule", "shard_stacked_schedule", "integrity_checksums",
           "verify_checksums"]


class KneadedIntegrityError(RuntimeError):
    """A kneaded weight's arrays no longer match their knead-time checksums.

    The kneaded form is an *exact* re-encoding (docs/DESIGN.md §2), which is
    precisely what makes corruption silent and dangerous: a flipped bit in a
    presence word or schedule array changes *which work items the kernel
    executes*, not just an output value.  Serving therefore checksums every
    array at knead time and verifies before trusting restored/transported
    weights (docs/DESIGN.md §10).
    """


def _crc32(x) -> int:
    """CRC32 of an array's raw bytes (host-side; forces a device fetch)."""
    return zlib.crc32(np.ascontiguousarray(np.asarray(x)).tobytes())


def _walk(obj, dotted: str):
    for part in dotted.split("."):
        obj = getattr(obj, part)
    return obj


def integrity_checksums(obj, fields: Tuple[str, ...]
                        ) -> Tuple[Tuple[str, int], ...]:
    """Per-field CRC32s over ``obj``'s (possibly dotted) array fields."""
    return tuple((name, _crc32(_walk(obj, name))) for name in fields)


def verify_checksums(obj, checksums: Tuple[Tuple[str, int], ...]
                     ) -> Tuple[str, ...]:
    """Names of fields whose current bytes mismatch ``checksums``."""
    return tuple(name for name, want in checksums
                 if _crc32(_walk(obj, name)) != want)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KneadedSchedule:
    """Compacted per-N-tile work lists for one kneaded weight.

    Attributes:
      counts:    int32 [N/n_block] — number of real work items per N-tile.
      plane_ids: int32 [N/n_block, num_work] — plane index of each item.
      ktile_ids: int32 [N/n_block, num_work] — K-tile index of each item.
                 Entries past ``counts[j]`` repeat the tile's last real item
                 (or 0 for all-empty tiles) so padded grid steps re-request
                 resident blocks.
      num_work:  static grid extent of the work dimension:
                 ``max(1, max(counts))`` (>= 1 so init/epilogue always run).
      total_work: static sum of counts == occupancy nonzero count == MXU
                 passes the kernel executes per M-step row of the grid.
      nk, n_tiles: static dense extents (K/ks, N/n_block) — the dense
                 schedule would be ``(B-1) * nk`` items per N-tile.
    """

    counts: jax.Array
    plane_ids: jax.Array
    ktile_ids: jax.Array
    num_work: int = dataclasses.field(metadata=dict(static=True), default=1)
    total_work: int = dataclasses.field(metadata=dict(static=True), default=0)
    nk: int = dataclasses.field(metadata=dict(static=True), default=0)
    n_tiles: int = dataclasses.field(metadata=dict(static=True), default=0)

    def dense_work(self, bits: int) -> int:
        """Work items the dense grid would execute: (B-1) * K/ks * N/n_block."""
        return (bits - 1) * self.nk * self.n_tiles

    def metadata_bytes(self) -> int:
        return (self.counts.size + self.plane_ids.size
                + self.ktile_ids.size) * 4


def build_schedule(occupancy_map: jax.Array) -> KneadedSchedule:
    """Flatten an occupancy presence map into a compacted schedule.

    Args:
      occupancy_map: {0,1} int array [B-1, K/ks, N/n_block] (the unpacked
        pass-mark metadata).  Host-side (numpy) — kneading is an offline
        conversion, and ``num_work``/``total_work`` must be static.
    Returns:
      A :class:`KneadedSchedule` whose items enumerate exactly the nonzero
      occupancy entries, k-major per N-tile.
    """
    occ = np.asarray(occupancy_map) != 0                   # [B-1, NK, NN]
    nb, nk, nn = occ.shape
    counts = occ.sum(axis=(0, 1)).astype(np.int32)         # [NN]
    num_work = max(1, int(counts.max(initial=0)))
    plane_ids = np.zeros((nn, num_work), np.int32)
    ktile_ids = np.zeros((nn, num_work), np.int32)
    for j in range(nn):
        # [NK, B-1] nonzero -> row-major: k_tile ascending, plane within
        kt, pb = np.nonzero(occ[:, :, j].T)
        c = kt.size
        if c:
            plane_ids[j, :c], ktile_ids[j, :c] = pb, kt
            plane_ids[j, c:], ktile_ids[j, c:] = pb[-1], kt[-1]
    return KneadedSchedule(
        counts=jnp.asarray(counts),
        plane_ids=jnp.asarray(plane_ids),
        ktile_ids=jnp.asarray(ktile_ids),
        num_work=num_work,
        total_work=int(counts.sum()),
        nk=nk,
        n_tiles=nn,
    )


def replay_schedule(a, kw, act_presence=None) -> jax.Array:
    """Executable spec of the compacted kernel: walk the schedule on the host.

    Replays, in numpy, exactly the work items the kernel's grid executes —
    per N-tile, per work item ``w < counts[j]``, one f32 dot accumulated into
    that item's plane segment, then the rear-adder-tree epilogue.  Used by
    the schedule property tests as the order-faithful oracle; bit-exact
    against both ``impl="planes"`` and ``impl="pallas"``.

    Control flow (which items run, in what order) is host-side numpy over the
    schedule arrays; the arithmetic itself is the same jnp ops as the planes
    oracle, so accumulation rounding is identical operation-for-operation.

    ``act_presence`` ({0,1} [nk], e.g. from
    :func:`repro.core.activation_occupancy.ktile_presence`) replays the
    activation-*intersected* order of the two-sided skip (docs/DESIGN.md
    §12): real items whose K-tile presence bit is 0 are dropped, survivors
    keep their k-major order — the oracle the masked Pallas walk is pinned
    bit-exact against, and (when the presence honestly reflects ``a``'s
    zeros) bit-exact against the unskipped replay too, since every dropped
    dot is exactly 0.

    Args:
      a:  [M, K] activations (K == kw.k, stored/padded dim).
      kw: a :class:`repro.core.kneading.KneadedWeight` with a schedule.
      act_presence: optional {0,1} [kw.k // kw.ks] activation K-tile
        presence bits; None replays the static weight-only walk.
    """
    from repro.core import bitplanes

    sched = kw.schedule
    mag = bitplanes.unpack_bits(kw.planes, axis=1)               # [B-1, K, N]
    sign = 1 - 2 * bitplanes.unpack_bits(kw.signs, axis=0).astype(jnp.int8)
    a32 = jnp.asarray(a, jnp.float32)
    counts = np.asarray(sched.counts)
    plane_ids = np.asarray(sched.plane_ids)
    ktile_ids = np.asarray(sched.ktile_ids)
    presence = None if act_presence is None else np.asarray(act_presence)
    ks, nb = kw.ks, kw.n_block
    m = a32.shape[0]
    weights = (2.0 ** jnp.arange(kw.bits - 1)).reshape(-1, 1, 1)
    out_tiles = []
    for j in range(sched.n_tiles):
        nsl = slice(j * nb, (j + 1) * nb)
        seg = [jnp.zeros((m, nb), jnp.float32) for _ in range(kw.bits - 1)]
        for w in range(int(counts[j])):                # real items only
            b, t = int(plane_ids[j, w]), int(ktile_ids[j, w])
            if presence is not None and not presence[t]:
                continue                               # activation-side skip
            ksl = slice(t * ks, (t + 1) * ks)
            plane = (mag[b, ksl, nsl].astype(jnp.int8)
                     * sign[ksl, nsl]).astype(jnp.float32)
            seg[b] = seg[b] + a32[:, ksl] @ plane      # S_b += A_t @ P_bt
        out_tiles.append(jnp.sum(jnp.stack(seg) * weights, axis=0))
    out = jnp.concatenate(out_tiles, axis=1)
    return out * kw.scale


# ---------------------------------------------------------------------------
# N-sharded schedules (docs/DESIGN.md §5)
# ---------------------------------------------------------------------------

PARTITIONS = ("contiguous", "balanced")


def _lpt_tile_slots(counts: np.ndarray, num_shards: int,
                    tiles_per_shard: int) -> np.ndarray:
    """LPT bin-packing of N-tiles onto shards with per-shard tile capacity.

    Longest-Processing-Time greedy: visit tiles by occupancy count
    descending (stable order, so equal counts keep their tile-index order)
    and place each on the least-loaded shard that still has a free tile
    slot, lowest shard index on ties.  Padding tiles (count 0) participate
    like any other tile — they fill leftover capacity and add no load.
    Deterministic: same counts => same packing, which the integrity
    checksums and the repair path rely on.

    Returns int32 ``slot`` with ``slot[j] = s * tiles_per_shard + p``: tile
    ``j`` lands in position ``p`` of shard ``s``.  ``slot`` is a bijection
    on ``range(num_shards * tiles_per_shard)`` — every tile is placed
    exactly once, every slot filled exactly once.
    """
    counts = np.asarray(counts, dtype=np.int64)
    total = num_shards * tiles_per_shard
    if counts.shape != (total,):
        raise ValueError(f"expected {total} padded tiles, got {counts.shape}")
    order = np.argsort(-counts, kind="stable")     # heaviest first
    load = np.zeros(num_shards, dtype=np.int64)
    fill = np.zeros(num_shards, dtype=np.int64)
    slot = np.empty(total, dtype=np.int32)
    for j in order:
        open_shards = np.flatnonzero(fill < tiles_per_shard)
        s = open_shards[np.argmin(load[open_shards])]  # argmin: lowest index
        slot[j] = s * tiles_per_shard + fill[s]
        load[s] += counts[j]
        fill[s] += 1
    return slot


def _balanced_tile_slots(counts: np.ndarray, num_shards: int,
                         tiles_per_shard: int) -> np.ndarray:
    """Tile→slot assignment for ``partition="balanced"``.

    LPT packing (:func:`_lpt_tile_slots`), falling back to the contiguous
    identity when that packing's max shard load is *worse*: LPT is a
    4/3-approximation, so a contiguous layout that happens to be optimal
    can beat the greedy (e.g. counts ``[3,3,0,2,2,2]`` at 2 shards pack
    greedily to max 7 while the contiguous slabs hit the optimal 6).
    Taking the better of the two makes balanced mode never worse than
    contiguous — the monotonicity the property suite pins.
    """
    slot = _lpt_tile_slots(counts, num_shards, tiles_per_shard)
    counts = np.asarray(counts, dtype=np.int64)
    lpt_max = np.bincount(slot // tiles_per_shard, weights=counts,
                          minlength=num_shards).max()
    cont_max = counts.reshape(num_shards, tiles_per_shard).sum(axis=1).max()
    if lpt_max <= cont_max:
        return slot
    return np.arange(counts.size, dtype=np.int32)


def _check_partition(partition: str) -> None:
    if partition not in PARTITIONS:
        raise ValueError(f"partition must be one of {PARTITIONS}, "
                         f"got {partition!r}")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedKneadedWeight:
    """A kneaded weight partitioned along N into per-device work-list shards.

    Every array carries a leading shard axis of extent ``num_shards``; placed
    with :func:`repro.runtime.sharding.kneaded_shardings`, that axis maps one
    slab per mesh device, and ``jax.shard_map`` hands each device its own
    planes/signs/scale slab *plus its own compacted schedule* — the device
    executes only the occupancy nonzeros of its N-tiles, never the dense
    tile count.

    Attributes:
      planes:    uint32 [S, B-1, K/32, n/S] — magnitude planes, N-sliced.
      signs:     uint32 [S, K/32, n/S].
      scale:     f32   [S, 1, n/S].
      counts:    int32 [S, T] per-shard work counts (T = tiles_per_shard).
      plane_ids / ktile_ids: int32 [S, T, num_work] per-shard work lists.
      tile_slot: int32 [S*T] — the tile→slot permutation: original N-tile
                 ``j`` lives in flattened packed slot ``tile_slot[j]``
                 (shard ``tile_slot[j] // T``, position ``% T``).  Identity
                 for ``partition="contiguous"``; for "balanced" it is both
                 the packing record and, directly, the gather index the
                 execution layer uses to restore original column order.
      num_shards, num_work, nk, tiles_per_shard: static grid extents; the
                 work dim is padded to the *global* max so every shard runs
                 the same program under shard_map.
      partition: static partitioning mode ("contiguous" | "balanced").
      shard_work: static per-shard occupancy-nonzero totals (the load each
                 device actually executes per M-step; see :meth:`imbalance`).
      bits, ks, n_block, k, n, k_orig, n_orig: as on ``KneadedWeight``; ``n``
                 is the sharded stored extent (tile padding may grow it when
                 N-tiles don't divide ``num_shards`` — padded tiles carry
                 count 0 and cost no MXU passes).
    """

    planes: jax.Array
    signs: jax.Array
    scale: jax.Array
    counts: jax.Array
    plane_ids: jax.Array
    ktile_ids: jax.Array
    tile_slot: jax.Array
    num_shards: int = dataclasses.field(metadata=dict(static=True), default=1)
    num_work: int = dataclasses.field(metadata=dict(static=True), default=1)
    nk: int = dataclasses.field(metadata=dict(static=True), default=0)
    tiles_per_shard: int = dataclasses.field(metadata=dict(static=True),
                                             default=0)
    shard_work: Tuple[int, ...] = dataclasses.field(
        metadata=dict(static=True), default=())
    bits: int = dataclasses.field(metadata=dict(static=True), default=8)
    ks: int = dataclasses.field(metadata=dict(static=True), default=256)
    n_block: int = dataclasses.field(metadata=dict(static=True), default=128)
    k: int = dataclasses.field(metadata=dict(static=True), default=0)
    n: int = dataclasses.field(metadata=dict(static=True), default=0)
    k_orig: int = dataclasses.field(metadata=dict(static=True), default=0)
    n_orig: int = dataclasses.field(metadata=dict(static=True), default=0)
    partition: str = dataclasses.field(metadata=dict(static=True),
                                       default="contiguous")
    # knead/shard-time per-field CRC32s ((field, crc) pairs; () = unchecked)
    checksums: Tuple[Tuple[str, int], ...] = dataclasses.field(
        metadata=dict(static=True), default=())

    _INTEGRITY_FIELDS = ("planes", "signs", "scale", "counts",
                         "plane_ids", "ktile_ids", "tile_slot")

    def with_checksums(self) -> "ShardedKneadedWeight":
        """Stamp shard-time CRC32s over every array field (host-side)."""
        return dataclasses.replace(
            self, checksums=integrity_checksums(self, self._INTEGRITY_FIELDS))

    def verify(self, strict: bool = False) -> Tuple[str, ...]:
        """Names of array fields whose bytes changed since sharding
        (empty tuple = intact, or no checksums recorded).  ``strict``
        raises :class:`KneadedIntegrityError` instead."""
        bad = verify_checksums(self, self.checksums)
        if bad and strict:
            raise KneadedIntegrityError(
                f"sharded kneaded weight [{self.k}x{self.n} "
                f"s={self.num_shards}] corrupt in: {', '.join(bad)}")
        return bad

    @property
    def shard_n(self) -> int:
        """Stored output columns held by each shard."""
        return self.n // self.num_shards

    @property
    def logical_k(self) -> int:
        return self.k_orig or self.k

    @property
    def logical_n(self) -> int:
        return self.n_orig or self.n

    @property
    def total_work(self) -> int:
        """Occupancy nonzeros across all shards == unsharded total_work."""
        return sum(self.shard_work)

    @property
    def _orig_n_tiles(self) -> int:
        """N-tiles of the weight before shard padding (== the unsharded
        schedule's n_tiles; knead already padded logical_n to n_block)."""
        return -(-self.logical_n // self.n_block)

    def dense_work(self) -> int:
        """Work items the dense grid would execute across all shards.

        Counts the pre-shard-padding tiles only, so this equals the
        unsharded ``KneadedSchedule.dense_work`` — all-empty shard-padding
        tiles must not inflate the denominator of skip ratios.
        """
        return (self.bits - 1) * self.nk * self._orig_n_tiles

    def schedule_for(self, s: int) -> KneadedSchedule:
        """Shard ``s``'s compacted schedule (the program each device runs)."""
        return KneadedSchedule(
            counts=self.counts[s],
            plane_ids=self.plane_ids[s],
            ktile_ids=self.ktile_ids[s],
            num_work=self.num_work,
            total_work=self.shard_work[s],
            nk=self.nk,
            n_tiles=self.tiles_per_shard,
        )

    def imbalance(self) -> dict:
        """Per-shard load report: executed work per device and skew.

        ``imbalance`` is max/mean shard work (1.0 == perfectly balanced); a
        shard with zero work contributes 0 to the mean but still holds a
        device, so heavily skewed occupancy shows up directly here.
        """
        work = list(self.shard_work)
        mean = sum(work) / max(1, len(work))
        return {
            "shard_work": work,
            "max": max(work) if work else 0,
            "mean": mean,
            "imbalance": (max(work) / mean) if mean else 1.0,
        }

    def metadata_bytes(self) -> int:
        return (self.counts.size + self.plane_ids.size
                + self.ktile_ids.size + self.tile_slot.size) * 4

    def packed_bytes(self) -> int:
        """HBM bytes across all shards: planes + signs + scales + schedule."""
        return (self.planes.size * 4 + self.signs.size * 4
                + self.scale.size * 4 + self.metadata_bytes())

    def dense_bf16_bytes(self) -> int:
        """bf16 bytes of the pre-shard-padding stored weight — same
        denominator as the unsharded report, so bytes_vs_bf16 keeps its
        meaning regardless of shard count."""
        return self.k * self._orig_n_tiles * self.n_block * 2


def _mesh_axis_size(mesh, axis: str) -> int:
    if isinstance(mesh, int):
        return mesh
    return mesh.shape[axis]


def shard_schedule(kw: "KneadedWeight",
                   mesh: Union[int, jax.sharding.Mesh],
                   axis: str = "model",
                   partition: str = "contiguous") -> ShardedKneadedWeight:
    """Partition a kneaded weight + its schedule along N for a device mesh.

    ``partition="contiguous"`` (default): each of the ``mesh.shape[axis]``
    shards receives a contiguous slab of N-tiles with exactly those tiles'
    compacted work lists — per-tile items and k-major order unchanged, so
    sharded outputs are bit-exact against the single-device kernel.

    ``partition="balanced"``: tiles are LPT bin-packed onto shards by their
    static occupancy counts (:func:`_lpt_tile_slots`), so
    ``max(shard_work)`` approaches ``ceil(total_work / S)`` regardless of
    where the occupancy landed.  The tile→slot permutation is recorded in
    ``tile_slot``; the execution layer gathers output N-blocks back into
    original order (``sac_matmul_pallas_sharded``), and because per-tile
    work lists and k-major order are untouched, the gathered output is
    still bit-exact against the single-device kernel (docs/DESIGN.md §11).

    When the N-tile count does not divide the shard count, all-empty
    padding tiles (count 0, zero weight columns, scale 1.0) are appended so
    every shard holds ``tiles_per_shard`` tiles; like knead padding, they
    cost metadata only, never an MXU pass, and the padded output columns
    sit past ``logical_n`` where callers already slice.  Under "balanced"
    the padding tiles join the packing as zero-cost filler instead of
    piling onto the last shard.

    Args:
      kw:   a :class:`repro.core.kneading.KneadedWeight`.
      mesh: the target mesh (or a plain int shard count for host-side
            analysis, e.g. the benchmark imbalance sweeps).
      axis: mesh axis name to shard over (the serving meshes call it
            "model" — out-channel partitioning is tensor parallelism).
      partition: "contiguous" | "balanced".
    Returns:
      A :class:`ShardedKneadedWeight` with one leading shard axis on every
      array, ready for ``runtime.sharding.kneaded_shardings`` placement.
    """
    _check_partition(partition)
    sched = kw.schedule
    num = _mesh_axis_size(mesh, axis)
    if num < 1:
        raise ValueError(f"shard count must be >= 1, got {num}")
    nn = sched.n_tiles
    tps = -(-nn // num)                       # tiles per shard (ceil)
    pad_tiles = tps * num - nn
    pad_cols = pad_tiles * kw.n_block
    n_pad = kw.n + pad_cols
    total = num * tps

    planes, signs = kw.planes, kw.signs
    scale = jnp.broadcast_to(jnp.asarray(kw.scale, jnp.float32)
                             .reshape(1, -1), (1, kw.n))
    counts = sched.counts
    plane_ids, ktile_ids = sched.plane_ids, sched.ktile_ids
    if pad_tiles:
        planes = jnp.pad(planes, ((0, 0), (0, 0), (0, pad_cols)))
        signs = jnp.pad(signs, ((0, 0), (0, pad_cols)))
        scale = jnp.pad(scale, ((0, 0), (0, pad_cols)), constant_values=1.0)
        counts = jnp.pad(counts, (0, pad_tiles))
        plane_ids = jnp.pad(plane_ids, ((0, pad_tiles), (0, 0)))
        ktile_ids = jnp.pad(ktile_ids, ((0, pad_tiles), (0, 0)))

    host_counts = np.asarray(counts)
    if partition == "balanced":
        slot = _balanced_tile_slots(host_counts, num, tps)
        inv = np.argsort(slot).astype(np.int32)  # inv[s] = tile in slot s
        inv_j = jnp.asarray(inv)
        nb_ = kw.bits - 1
        kwords_ = kw.k // 32
        planes = jnp.take(planes.reshape(nb_, kwords_, total, kw.n_block),
                          inv_j, axis=2).reshape(nb_, kwords_, n_pad)
        signs = jnp.take(signs.reshape(kwords_, total, kw.n_block),
                         inv_j, axis=1).reshape(kwords_, n_pad)
        scale = jnp.take(scale.reshape(1, total, kw.n_block),
                         inv_j, axis=1).reshape(1, n_pad)
        counts = jnp.take(counts, inv_j, axis=0)
        plane_ids = jnp.take(plane_ids, inv_j, axis=0)
        ktile_ids = jnp.take(ktile_ids, inv_j, axis=0)
        host_counts = host_counts[inv]
    else:
        slot = np.arange(total, dtype=np.int32)

    shard_n = n_pad // num
    nb = kw.bits - 1
    kwords = kw.k // 32
    shard_work = tuple(
        int(c) for c in host_counts.reshape(num, tps).sum(axis=1))
    return ShardedKneadedWeight(
        planes=planes.reshape(nb, kwords, num, shard_n).transpose(2, 0, 1, 3),
        signs=signs.reshape(kwords, num, shard_n).transpose(1, 0, 2),
        scale=scale.reshape(1, num, shard_n).transpose(1, 0, 2),
        counts=counts.reshape(num, tps),
        plane_ids=plane_ids.reshape(num, tps, sched.num_work),
        ktile_ids=ktile_ids.reshape(num, tps, sched.num_work),
        tile_slot=jnp.asarray(slot),
        num_shards=num,
        num_work=sched.num_work,
        nk=sched.nk,
        tiles_per_shard=tps,
        shard_work=shard_work,
        bits=kw.bits, ks=kw.ks, n_block=kw.n_block,
        k=kw.k, n=n_pad,
        k_orig=kw.k_orig, n_orig=kw.n_orig or (kw.n if pad_tiles else 0),
        partition=partition,
    ).with_checksums()


# ---------------------------------------------------------------------------
# Stacked (scan-layer) N-sharded schedules (docs/DESIGN.md §8)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedStackedKneadedWeight(ShardedKneadedWeight):
    """A stacked [L, K, N] kneaded weight sharded along N, per layer.

    Every array field of :class:`ShardedKneadedWeight` gains a leading
    *layer* axis in front of the shard axis: ``planes [L, S, B-1, K/32,
    n/S]``, ``counts [L, S, T]``, work lists ``[L, S, T, num_work]``, and so
    on.  The layer axis stays outermost because ``jax.lax.scan`` slices
    leading axes only — scanning this pytree as ``xs`` hands the body layer
    *l*'s arrays with their leading shard axis intact, i.e. exactly the
    per-layer sharded weight ``shard_schedule(knead_padded(w[l]))`` would
    build (up to the work dim, padded to the cross-layer/cross-shard max so
    every layer and every shard runs the same kernel program).  The shard
    axis (axis 1 here; axis 0 after the scan slice) is the one
    ``runtime.sharding`` places on the mesh.

    Statics: ``num_layers`` is the stack extent; ``layer_shard_work[l][s]``
    the occupancy-nonzero count layer *l* dispatches on shard *s* (each row
    partitions that layer's unsharded ``total_work``); the inherited
    ``shard_work[s]`` aggregates over layers — the per-device load of one
    full forward pass through the stack.
    """

    num_layers: int = dataclasses.field(metadata=dict(static=True), default=0)
    layer_shard_work: Tuple[Tuple[int, ...], ...] = dataclasses.field(
        metadata=dict(static=True), default=())

    def layer_schedule_for(self, layer: int, s: int) -> KneadedSchedule:
        """Layer ``layer``'s compacted schedule on shard ``s`` (host-side
        full object; a scan-sliced per-layer object uses the inherited
        :meth:`ShardedKneadedWeight.schedule_for` instead)."""
        return KneadedSchedule(
            counts=self.counts[layer, s],
            plane_ids=self.plane_ids[layer, s],
            ktile_ids=self.ktile_ids[layer, s],
            num_work=self.num_work,
            total_work=self.layer_shard_work[layer][s],
            nk=self.nk,
            n_tiles=self.tiles_per_shard,
        )

    def dense_work(self) -> int:
        """Dense-grid work items across all layers and shards (stack-level
        accounting, matching the stacked ``total_work`` convention)."""
        return self.num_layers * super().dense_work()

    def dense_bf16_bytes(self) -> int:
        return self.num_layers * super().dense_bf16_bytes()

    def layer_imbalance(self, layer: int) -> dict:
        """Per-shard load report for one layer (same keys as
        :meth:`ShardedKneadedWeight.imbalance`)."""
        work = list(self.layer_shard_work[layer])
        mean = sum(work) / max(1, len(work))
        return {
            "shard_work": work,
            "max": max(work) if work else 0,
            "mean": mean,
            "imbalance": (max(work) / mean) if mean else 1.0,
        }

    def imbalance(self) -> dict:
        """Aggregate per-shard load over the whole stack, plus the worst
        single layer's skew (a layer whose occupancy lands on one shard
        serializes that layer even if the stack totals balance)."""
        rep = super().imbalance()
        if self.layer_shard_work:
            rep["max_layer_imbalance"] = max(
                self.layer_imbalance(layer)["imbalance"]
                for layer in range(self.num_layers))
        return rep


def shard_stacked_schedule(kw: "KneadedWeight",
                           mesh: Union[int, jax.sharding.Mesh],
                           axis: str = "model",
                           partition: str = "contiguous",
                           ) -> ShardedStackedKneadedWeight:
    """Partition a stacked [L, K, N] kneaded weight along N for a mesh.

    ``kw`` is a stacked weight from :func:`repro.core.kneading.knead_stacked`
    (leading layer axis on every array, schedule ``counts [L, NN]`` / work
    lists ``[L, NN, num_work]``).  Every layer's per-N-tile work lists are
    partitioned exactly as :func:`shard_schedule` partitions one layer's —
    shard *s* of layer *l* takes the same contiguous slab of N-tiles with
    those tiles' compacted items, k-major order untouched, so the sharded
    stack is bit-exact against the unsharded one layer by layer.  All layers
    share the (already cross-layer-padded) ``num_work``, so the whole stack
    runs one kernel program.

    ``partition="balanced"`` repartitions **per layer**: each layer's tiles
    are LPT-packed on that layer's own counts (occupancy skew is per-layer
    — one layer's hot tiles are another's empty ones), giving ``tile_slot``
    a leading layer axis ``[L, S*T]`` that ``jax.lax.scan`` slices together
    with the weight arrays, so the per-layer gather in the execution layer
    sees exactly its layer's permutation.  The shared cross-layer
    ``num_work`` pad is untouched — every layer still runs one kernel
    program, only its tile→shard placement differs.

    Indivisible N-tile counts append all-empty padding tiles per layer (the
    same tiles on every layer — the stack shares [K, N]); padded columns sit
    past ``logical_n`` where callers already slice.

    Args:
      kw:   a *stacked* :class:`repro.core.kneading.KneadedWeight`.
      mesh: target mesh or plain int shard count (host-side analysis).
      axis: mesh axis name for the shard dimension.
      partition: "contiguous" | "balanced" (per-layer LPT).
    Returns:
      A :class:`ShardedStackedKneadedWeight` with axes ``[L, S, ...]`` —
      scan-sliceable per layer, shard axis placed by
      ``runtime.sharding.kneaded_shardings``.
    """
    _check_partition(partition)
    sched = kw.schedule
    if kw.planes.ndim != 4:
        raise ValueError("shard_stacked_schedule expects a stacked kneaded "
                         f"weight (planes [L, B-1, K/32, N]), got planes "
                         f"shape {tuple(kw.planes.shape)}")
    num = _mesh_axis_size(mesh, axis)
    if num < 1:
        raise ValueError(f"shard count must be >= 1, got {num}")
    layers = kw.planes.shape[0]
    nn = sched.n_tiles
    tps = -(-nn // num)                       # tiles per shard (ceil)
    pad_tiles = tps * num - nn
    pad_cols = pad_tiles * kw.n_block
    n_pad = kw.n + pad_cols
    total = num * tps

    planes, signs = kw.planes, kw.signs                  # [L, B-1, K/32, N]
    scale = jnp.broadcast_to(
        jnp.asarray(kw.scale, jnp.float32).reshape(layers, 1, -1),
        (layers, 1, kw.n))
    counts = sched.counts                                 # [L, NN]
    plane_ids, ktile_ids = sched.plane_ids, sched.ktile_ids
    if pad_tiles:
        planes = jnp.pad(planes, ((0, 0),) * 3 + ((0, pad_cols),))
        signs = jnp.pad(signs, ((0, 0),) * 2 + ((0, pad_cols),))
        scale = jnp.pad(scale, ((0, 0), (0, 0), (0, pad_cols)),
                        constant_values=1.0)
        counts = jnp.pad(counts, ((0, 0), (0, pad_tiles)))
        plane_ids = jnp.pad(plane_ids, ((0, 0), (0, pad_tiles), (0, 0)))
        ktile_ids = jnp.pad(ktile_ids, ((0, 0), (0, pad_tiles), (0, 0)))

    host_counts = np.asarray(counts)                      # [L, total]
    if partition == "balanced":
        slot = np.stack([_balanced_tile_slots(host_counts[layer], num, tps)
                         for layer in range(layers)])     # [L, total]
        inv = np.argsort(slot, axis=1).astype(np.int32)
        inv_j = jnp.asarray(inv)
        nb_ = kw.bits - 1
        kwords_ = kw.k // 32
        planes = jnp.take_along_axis(
            planes.reshape(layers, nb_, kwords_, total, kw.n_block),
            inv_j[:, None, None, :, None], axis=3,
        ).reshape(layers, nb_, kwords_, n_pad)
        signs = jnp.take_along_axis(
            signs.reshape(layers, kwords_, total, kw.n_block),
            inv_j[:, None, :, None], axis=2,
        ).reshape(layers, kwords_, n_pad)
        scale = jnp.take_along_axis(
            scale.reshape(layers, 1, total, kw.n_block),
            inv_j[:, None, :, None], axis=2,
        ).reshape(layers, 1, n_pad)
        counts = jnp.take_along_axis(counts, inv_j, axis=1)
        plane_ids = jnp.take_along_axis(plane_ids, inv_j[:, :, None], axis=1)
        ktile_ids = jnp.take_along_axis(ktile_ids, inv_j[:, :, None], axis=1)
        host_counts = np.take_along_axis(host_counts, inv, axis=1)
    else:
        slot = np.broadcast_to(np.arange(total, dtype=np.int32),
                               (layers, total)).copy()

    shard_n = n_pad // num
    nb = kw.bits - 1
    kwords = kw.k // 32
    per_layer_work = host_counts.reshape(layers, num, tps).sum(axis=2)
    layer_shard_work = tuple(tuple(int(c) for c in row)
                             for row in per_layer_work)
    shard_work = tuple(int(c) for c in per_layer_work.sum(axis=0))
    return ShardedStackedKneadedWeight(
        planes=planes.reshape(layers, nb, kwords, num, shard_n)
                     .transpose(0, 3, 1, 2, 4),
        signs=signs.reshape(layers, kwords, num, shard_n)
                   .transpose(0, 2, 1, 3),
        scale=scale.reshape(layers, 1, num, shard_n).transpose(0, 2, 1, 3),
        counts=counts.reshape(layers, num, tps),
        plane_ids=plane_ids.reshape(layers, num, tps, sched.num_work),
        ktile_ids=ktile_ids.reshape(layers, num, tps, sched.num_work),
        tile_slot=jnp.asarray(slot),
        num_shards=num,
        num_work=sched.num_work,
        nk=sched.nk,
        tiles_per_shard=tps,
        shard_work=shard_work,
        bits=kw.bits, ks=kw.ks, n_block=kw.n_block,
        k=kw.k, n=n_pad,
        k_orig=kw.k_orig, n_orig=kw.n_orig or (kw.n if pad_tiles else 0),
        partition=partition,
        num_layers=layers,
        layer_shard_work=layer_shard_work,
    ).with_checksums()
