"""Split-and-Accumulate (SAC) — the paper's computing pattern, as JAX ops.

MAC computes ``sum_i A_i * W_i`` pair-wise.  SAC (paper Eq. 2) regroups by bit:

    sum_i A_i * W_i  =  sum_b 2^b * ( sum_i A_i * W_i^b )

keeping one *segment accumulator* per bit position and performing the
shift-and-add **once** at the end (the rear adder tree).  Three interchangeable
implementations, all numerically identical on quantized weights:

* ``impl="planes"`` — the paper-faithful decomposition: one MXU pass per
  non-empty bit plane, per-plane segment accumulators, single 2^b reduction.
  (Pure jnp; the Pallas kernel in ``repro.kernels.sac_matmul`` is the tiled
  TPU version driven by the compacted occupancy schedule — this is its
  semantic oracle and replays the schedule's accumulation order.)
* ``impl="int"``    — the production path: one integer-code matmul with the
  scale applied once in the epilogue (SAC's "defer all shifting/scaling to
  the rear" applied at tile granularity).  Same math, MXU-optimal.
* ``impl="pallas"`` — dispatch to the Pallas kernel (interpret=True on CPU).

All paths return ``A @ dequantize(Wq)`` exactly (float32 accumulation).
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import bitplanes
from repro.core.kneading import KneadedWeight, ShardedKneadedWeight, knead

__all__ = ["SAC_IMPLS", "sac_matmul", "sac_matmul_planes", "sac_matmul_int",
           "TetrisLinear"]


def sac_matmul_planes(a: jax.Array, kw: KneadedWeight) -> jax.Array:
    """Paper-faithful SAC: per-plane matmuls + single rear shift-and-add.

    Replays the Pallas kernel's *compacted-schedule order*: K tiles of extent
    ``ks`` ascend (k-major, the schedule's sort key) with planes walked within
    each tile, each partial dot accumulating into its plane's segment S_b.
    The work items the schedule never dispatches are exactly the all-zero
    plane tiles, whose partial is exactly 0.0 — adding it is a bitwise no-op
    — so this dense replay realizes the same per-segment accumulation
    sequence as the compacted kernel, and the parity tests assert bit-exact
    *equality*, not closeness.  (``repro.core.schedule.replay_schedule`` is
    the item-by-item sparse replay; the property tests pin all three paths
    equal.)  Output = scale * sum_b 2^b S_b — the single rear adder tree.
    """
    mag = bitplanes.unpack_bits(kw.planes, axis=1)                 # [B-1, K, N]
    sign = 1 - 2 * bitplanes.unpack_bits(kw.signs, axis=0).astype(jnp.int8)
    a32 = a.astype(jnp.float32)
    nk = kw.k // kw.ks
    planes = [(mag[b].astype(jnp.int8) * sign).astype(jnp.float32)
              for b in range(kw.bits - 1)]
    segments = [jnp.zeros((a32.shape[0], kw.n), jnp.float32)
                for _ in range(kw.bits - 1)]
    for t in range(nk):                      # K tiles ascending (grid order)
        sl = slice(t * kw.ks, (t + 1) * kw.ks)
        for b in range(kw.bits - 1):         # planes within the K tile
            segments[b] = segments[b] + a32[:, sl] @ planes[b][sl]
    seg = jnp.stack(segments)                                      # [B-1, M, N]
    weights = (2.0 ** jnp.arange(kw.bits - 1)).reshape(-1, 1, 1)
    out = jnp.sum(seg * weights, axis=0)                           # rear adder
    return out * kw.scale                                          # scale once


def sac_matmul_int(a: jax.Array, q: jax.Array, scale: jax.Array) -> jax.Array:
    """Integer-code matmul with deferred (epilogue) scaling.

    ``q`` is the signed code matrix [K, N]; scale broadcast [1, N].  f32
    accumulation; codes cast to f32 are exact for |q| < 2^24 (bits <= 16).
    """
    out = jnp.dot(a.astype(jnp.float32), q.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return out * scale


SAC_IMPLS = ("float", "int", "planes", "pallas")


def sac_matmul(
    a: jax.Array,
    kw: KneadedWeight,
    impl: Literal["float", "planes", "int", "pallas"] = "int",
    *,
    skip_activations: bool = False,
) -> jax.Array:
    """SAC matmul of activations [..., K] against a kneaded weight [K, N].

    Accepts activations sized to either the stored (padded) or the logical
    reduction dim: logical inputs are zero-padded up to ``kw.k`` and the
    output is sliced back to ``kw.logical_n`` — exact, since padded rows/
    channels are all-zero codes.

    ``skip_activations=True`` arms the runtime activation-side skip
    (docs/DESIGN.md §12) on the Pallas paths, gated to the decode-GEMV
    regime: it engages only when the flattened activation has at most
    ``GEMV_ROWS_MAX`` (8) rows — a decode step — where per-K-tile presence
    bits from the activation row are intersected into the kernel's schedule
    walk.  Prefill-shaped calls (M > 8) silently fall back to the static
    weight-only skip: unioned presence over hundreds of rows is all ones,
    so masking would cost runtime for zero skipped work.  The switch never
    changes results on any impl: dropped items contribute exactly 0.0, so
    the non-pallas impls ("planes"/"int"/"float"), which ignore the flag,
    double as the skip-off oracles the parity tests compare against.

    impl="float" dequantizes the codes and runs one f32 matmul — the
    quantized-model reference the SAC paths must match (identical math to
    "int"; kept so the model-level dispatch matrix is closed under this op).

    N-sharded weights (``ShardedKneadedWeight``, including per-layer
    scan slices of a ``ShardedStackedKneadedWeight``) execute through the
    Pallas kernel only — one launch per device of the serving mesh
    installed via :func:`repro.runtime.sharding.serving_mesh`, or the
    serial single-device shard walk when no mesh is installed (the parity
    oracle; docs/DESIGN.md §8).
    """
    lead = a.shape[:-1]
    a2 = a.reshape(-1, a.shape[-1])
    if a2.shape[1] not in (kw.k, kw.logical_k):
        raise ValueError(
            f"activation K {a2.shape[1]} matches neither stored "
            f"{kw.k} nor logical {kw.logical_k}")
    from repro.core.activation_occupancy import GEMV_ROWS_MAX
    skip = bool(skip_activations) and a2.shape[0] <= GEMV_ROWS_MAX
    if isinstance(kw, ShardedKneadedWeight):
        if impl != "pallas":
            raise ValueError("sharded kneaded weights execute through the "
                             f"Pallas kernel only, got impl={impl!r}")
        if kw.planes.ndim == 5:
            raise ValueError(
                "a stacked sharded weight reached sac_matmul un-sliced — "
                "scan over its layer axis (or index one layer) first")
        from repro.kernels.sac_matmul.ops import sac_matmul_pallas_sharded
        from repro.runtime.sharding import current_serving_mesh
        mesh, axis = current_serving_mesh()
        out = sac_matmul_pallas_sharded(a2, kw, mesh, axis,
                                        skip_activations=skip)
    elif impl == "pallas":
        # the ops-level wrapper owns the logical-K zero-pad policy
        from repro.kernels.sac_matmul.ops import sac_matmul_pallas
        out = sac_matmul_pallas(a2, kw, skip_activations=skip)
    else:
        if a2.shape[1] != kw.k:
            a2 = jnp.pad(a2, ((0, 0), (0, kw.k - a2.shape[1])))
        if impl == "planes":
            # Replay the kernel's padded M: the pallas grid rounds M up to
            # its block (zero rows — exact), and XLA CPU picks *different*
            # dense-matmul micro-kernels for, e.g., M=7 vs M=8 at wide N,
            # which changes f32 reduction order at ~1e-6.  Padding here
            # keeps the oracle operand-for-operand comparable, so planes ==
            # pallas stays bitwise at every M.
            from repro.kernels.sac_matmul.ops import m_block
            m0 = a2.shape[0]
            pad = (-m0) % m_block(m0)
            if pad:
                a2 = jnp.pad(a2, ((0, pad), (0, 0)))
            out = sac_matmul_planes(a2, kw)[:m0]
        elif impl in ("int", "float"):
            from repro.core.kneading import unknead  # codes * scale, exact
            out = a2.astype(jnp.float32) @ unknead(kw)
        else:
            raise ValueError(f"unknown impl {impl!r}")
    out = out[:, :kw.logical_n]
    return out.reshape(lead + (kw.logical_n,)).astype(a.dtype)


class TetrisLinear:
    """A linear layer whose weights live in kneaded form (serving path).

    Functional: ``TetrisLinear.knead_params(w, bits, ks)`` converts a trained
    float [K, N] kernel; ``TetrisLinear.apply(params, x)`` runs SAC matmul.
    """

    @staticmethod
    def knead_params(w: jax.Array, bits: int = 8, ks: int = 256) -> KneadedWeight:
        return knead(w, bits=bits, ks=ks)

    @staticmethod
    def apply(params: KneadedWeight, x: jax.Array,
              impl: Literal["float", "planes", "int", "pallas"] = "int",
              ) -> jax.Array:
        return sac_matmul(x, params, impl=impl)
