"""Tetris core — weight kneading + SAC, the paper's contribution in JAX.

Public API:
  quantize / dequantize / fake_quantize      (fixed-point substrate)
  knead / unknead / KneadedWeight            (the kneaded weight format)
  shard_schedule / ShardedKneadedWeight      (N-sharded serving shards, §5)
  kneaded_cycles / kneading_ratio            (paper Fig 3 cycle semantics)
  sac_matmul / TetrisLinear                  (SAC computing pattern)
  weight_bit_stats                           (Table 1 / Fig 2 statistics)
  cost_model                                 (DaDN / PRA / Tetris cycle model)
"""
from repro.core.quantization import (
    QuantizedTensor, quantize, dequantize, fake_quantize, storage_dtype,
)
from repro.core.kneading import (
    KneadedWeight, knead, unknead, kneaded_cycles, kneading_ratio,
)
from repro.core.schedule import (
    KneadedSchedule, ShardedKneadedWeight, build_schedule, replay_schedule,
    shard_schedule,
)
from repro.core.sac import sac_matmul, sac_matmul_planes, sac_matmul_int, TetrisLinear
from repro.core.stats import WeightBitStats, weight_bit_stats, aggregate_stats
from repro.core import bitplanes, cost_model

__all__ = [
    "QuantizedTensor", "quantize", "dequantize", "fake_quantize", "storage_dtype",
    "KneadedWeight", "knead", "unknead", "kneaded_cycles", "kneading_ratio",
    "KneadedSchedule", "ShardedKneadedWeight", "build_schedule",
    "replay_schedule", "shard_schedule",
    "sac_matmul", "sac_matmul_planes", "sac_matmul_int", "TetrisLinear",
    "WeightBitStats", "weight_bit_stats", "aggregate_stats",
    "bitplanes", "cost_model",
]
