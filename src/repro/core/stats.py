"""Zero-value / zero-bit statistics — reproduces the paper's Table 1 & Fig 2.

All statistics are computed on *quantized codes* (the representation the
accelerator sees), in sign-magnitude form consistent with
``bitplanes.magnitude_planes``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplanes
from repro.core.quantization import quantize

__all__ = ["WeightBitStats", "weight_bit_stats", "aggregate_stats"]


@dataclasses.dataclass
class WeightBitStats:
    """Bit-level statistics of one weight tensor (paper Table 1 / Fig 2)."""

    n_weights: int
    zero_value_frac: float          # Table 1 col 2
    zero_bit_frac: float            # Table 1 col 3 (over B-1 magnitude bits)
    per_bit_density: np.ndarray     # Fig 2: essential-bit (1s) fraction per position
    bits: int

    def as_row(self) -> Dict[str, float]:
        return {
            "n_weights": self.n_weights,
            "zero_value_pct": 100.0 * self.zero_value_frac,
            "zero_bit_pct": 100.0 * self.zero_bit_frac,
        }


def weight_bit_stats(w: jax.Array, bits: int = 16) -> WeightBitStats:
    """Quantize ``w`` to ``bits`` fixed point and measure bit-level slack."""
    w2 = w.reshape(-1, w.shape[-1]) if w.ndim > 1 else w.reshape(-1, 1)
    qt = quantize(w2, bits=bits, axis=None)  # per-tensor: paper-faithful
    q = qt.q
    zero_vals = jnp.mean((q == 0).astype(jnp.float32))
    mag = jnp.abs(q.astype(jnp.int32))
    # per-position essential density over B-1 magnitude bit positions
    shifts = jnp.arange(bits - 1, dtype=jnp.int32)
    per_bit = jnp.stack([jnp.mean(((mag >> b) & 1).astype(jnp.float32))
                         for b in shifts])
    total_essential = jnp.mean(
        bitplanes.popcount(mag).astype(jnp.float32)) / (bits - 1)
    return WeightBitStats(
        n_weights=int(q.size),
        zero_value_frac=float(zero_vals),
        zero_bit_frac=float(1.0 - total_essential),
        per_bit_density=np.asarray(per_bit),
        bits=bits,
    )


def aggregate_stats(stats: Dict[str, WeightBitStats]) -> WeightBitStats:
    """Weight-count-weighted aggregate across layers (the GeoMean row)."""
    total = sum(s.n_weights for s in stats.values())
    zv = sum(s.zero_value_frac * s.n_weights for s in stats.values()) / total
    zb = sum(s.zero_bit_frac * s.n_weights for s in stats.values()) / total
    bits = next(iter(stats.values())).bits
    pb = sum(s.per_bit_density * s.n_weights for s in stats.values()) / total
    return WeightBitStats(
        n_weights=total, zero_value_frac=zv, zero_bit_frac=zb,
        per_bit_density=pb, bits=bits,
    )
