"""Weight kneading — the paper's core contribution, in two forms.

1. **Algorithmic model** (:func:`kneaded_cycles`): the exact combinatorial
   semantics of Fig 3.  Within a group of ``ks`` (kneading stride) weights in
   a reduction lane, essential bits bubble up per bit-column independently, so
   the group compresses from ``ks`` weight-cycles to

       cycles(group) = max_b  popcount_b(group)

   (the tallest bit-column of the group).  Zero-value weights vanish for free
   (all their columns are empty) — the paper's "two orthogonal dimensions" of
   slack.  This drives the cycle-accurate cost model that reproduces the
   paper's Figs 8/10/11.

2. **TPU kneaded format** (:class:`KneadedWeight` / :func:`knead`): the
   deployable artifact — sign-magnitude bit planes, bit-packed 32/word along
   K, with per-(plane, tile) occupancy presence bits compacted into a
   :class:`~repro.core.schedule.KneadedSchedule` so the Pallas kernel
   dispatches occupied tiles *only*, and the storage footprint is
   ``bits/16`` of bf16.  Kneading is *exact*:
   ``unknead(knead(w)) == dequantize(quantize(w))`` bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import bitplanes
from repro.core.quantization import QuantizedTensor, quantize
from repro.core.schedule import (KneadedIntegrityError, KneadedSchedule,
                                 ShardedKneadedWeight,
                                 ShardedStackedKneadedWeight, build_schedule,
                                 integrity_checksums, shard_schedule,
                                 shard_stacked_schedule, verify_checksums)

__all__ = [
    "KNEADABLE_NAMES",
    "KneadedIntegrityError",
    "KneadedWeight",
    "ShardedKneadedWeight",
    "ShardedStackedKneadedWeight",
    "shard_schedule",
    "shard_stacked_schedule",
    "knead",
    "knead_padded",
    "knead_stacked",
    "kneadable_dims",
    "kneaded_codes",
    "reknead_like",
    "unknead",
    "kneaded_cycles",
    "kneading_ratio",
]


# Weight-name suffixes eligible for kneading / quantized serving: 2-D
# projection matrices, their stacked scan-layer forms, and MoE expert banks.
# Embeddings stay bf16 (gather path); norms/gates are not matmuls.  Single
# source of truth shared by inference.engine.knead_params and launch.specs
# (they used to carry drifting copies).
KNEADABLE_NAMES = ("wq", "wk", "wv", "wo", "wi", "wi_gate", "wi_up", "up",
                   "down", "w_in", "w_out", "in_proj", "out_proj", "unembed")


# ---------------------------------------------------------------------------
# 1. The paper-faithful kneading cycle model (Fig 3 semantics)
# ---------------------------------------------------------------------------

def kneaded_cycles(q: jax.Array, bits: int, ks: int) -> jax.Array:
    """Cycles to process each KS-group of a weight lane after kneading.

    Args:
      q:    integer codes laid out [K, ...] with K the reduction (lane) axis.
      bits: fixed-point width (B); magnitude planes are B-1.
      ks:   kneading stride — group size along K.  K % ks must be 0.
    Returns:
      int32 [K // ks, ...]: per-group kneaded cycle count,
      ``max_b popcount_b(group)``.  Un-kneaded cost is ``ks`` per group.
    """
    k = q.shape[0]
    if k % ks:
        raise ValueError(f"lane length {k} not divisible by ks={ks}")
    planes = bitplanes.magnitude_planes(q, bits)          # [B-1, K, ...]
    g = planes.reshape((planes.shape[0], k // ks, ks) + planes.shape[2:])
    counts = jnp.sum(g.astype(jnp.int32), axis=2)          # [B-1, K/ks, ...]
    return jnp.max(counts, axis=0)                         # [K/ks, ...]


def kneading_ratio(q: jax.Array, bits: int, ks: int) -> jax.Array:
    """T_ks / T_base of Fig 11: kneaded cycles over un-kneaded cycles."""
    cyc = kneaded_cycles(q, bits, ks)
    return jnp.sum(cyc) / (cyc.size * ks)


# ---------------------------------------------------------------------------
# 2. The deployable TPU kneaded-weight format
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KneadedWeight:
    """A [K, N] weight matrix in kneaded (packed bit-plane) form.

    Attributes:
      planes:    uint32 [B-1, K/32, N] — magnitude planes, bit-packed along K.
      signs:     uint32 [K/32, N]      — sign bits (1 = negative), packed.
      scale:     f32 broadcastable to [1, N] — per-output-channel scale.
      occupancy: uint32 [B-1, ceil(K/ks/32), N/n_block] — per-(plane, tile)
                 essential-bit presence, bit-packed along the K-tile axis
                 (the pass-mark metadata; see :meth:`occupancy_map`).
      schedule:  the occupancy map compacted into per-N-tile work lists of
                 non-empty (plane, k_tile) items — what the kernel actually
                 executes (scalar-prefetched; built once at knead time).
      bits:      static fixed-point width B.
      ks:        static kneading stride == kernel K-tile extent.
      n_block:   static kernel N-tile extent for occupancy granularity.
      k, n:      static *stored* (tile-aligned) dims.
      k_orig, n_orig: static logical dims before alignment padding (0 means
                 "same as stored" — the un-padded case).  Padding rows/cols
                 are all-zero codes whose occupancy is 0, so the kernel skips
                 them for free and the padded matmul is exact.

    A *stacked* kneaded weight (:func:`knead_stacked`) carries one or more
    extra leading stack axes on every array field while the statics describe
    the per-slice dims — ``jax.lax.scan`` over such a pytree slices out the
    leading axis one step at a time (a [L, E, K, N] MoE bank scans to
    per-layer [E, K, N] banks, which scan again to plain per-expert
    ``KneadedWeight``s).
    """

    planes: jax.Array
    signs: jax.Array
    scale: jax.Array
    occupancy: jax.Array
    schedule: KneadedSchedule
    bits: int = dataclasses.field(metadata=dict(static=True), default=8)
    ks: int = dataclasses.field(metadata=dict(static=True), default=256)
    n_block: int = dataclasses.field(metadata=dict(static=True), default=128)
    k: int = dataclasses.field(metadata=dict(static=True), default=0)
    n: int = dataclasses.field(metadata=dict(static=True), default=0)
    k_orig: int = dataclasses.field(metadata=dict(static=True), default=0)
    n_orig: int = dataclasses.field(metadata=dict(static=True), default=0)
    # knead-time per-field CRC32s ((field, crc) pairs; () = unchecked).
    # Kneading is an exact re-encoding, so a silently corrupted plane,
    # presence word, or schedule entry changes *which work executes* —
    # detection has to be byte-level, not numeric (docs/DESIGN.md §10).
    checksums: Tuple[Tuple[str, int], ...] = dataclasses.field(
        metadata=dict(static=True), default=())

    _INTEGRITY_FIELDS = ("planes", "signs", "scale", "occupancy",
                         "schedule.counts", "schedule.plane_ids",
                         "schedule.ktile_ids")

    @property
    def shape(self):
        return (self.k, self.n)

    def with_checksums(self) -> "KneadedWeight":
        """Stamp knead-time CRC32s over every array field (host-side;
        call outside jit — checksumming forces a device fetch)."""
        return dataclasses.replace(
            self, checksums=integrity_checksums(self, self._INTEGRITY_FIELDS))

    def verify(self, strict: bool = False) -> Tuple[str, ...]:
        """Names of array fields whose bytes changed since knead time.

        Returns an empty tuple when intact (or when no checksums were
        recorded — pre-integrity weights verify vacuously).  ``strict``
        raises :class:`~repro.core.schedule.KneadedIntegrityError` listing
        the corrupted fields instead of returning them.
        """
        bad = verify_checksums(self, self.checksums)
        if bad and strict:
            raise KneadedIntegrityError(
                f"kneaded weight [{self.logical_k}x{self.logical_n}] "
                f"corrupt in: {', '.join(bad)}")
        return bad

    @property
    def logical_k(self) -> int:
        """Reduction dim of the original weight (before alignment padding)."""
        return self.k_orig or self.k

    @property
    def logical_n(self) -> int:
        """Output dim of the original weight (before alignment padding)."""
        return self.n_orig or self.n

    def occupancy_map(self) -> jax.Array:
        """Unpacked presence map, int32 {0,1} [B-1, K/ks, N/n_block]."""
        return bitplanes.unpack_presence(self.occupancy, self.k // self.ks)

    def with_occupancy(self, occupancy_map: jax.Array) -> "KneadedWeight":
        """Replace the occupancy map, re-deriving packed bits + schedule.

        The kernel executes the *schedule*, so tampering with occupancy (as
        the skip-semantics tests do) must go through here to take effect.
        Checksums are re-stamped: this is a *legitimate* re-derivation, not
        corruption, so the result must verify clean.
        """
        return dataclasses.replace(
            self,
            occupancy=bitplanes.pack_presence(occupancy_map),
            schedule=build_schedule(occupancy_map),
        ).with_checksums()

    def shard(self, mesh, axis: str = "model",
              partition: str = "contiguous") -> ShardedKneadedWeight:
        """Partition this weight + schedule along N for a device mesh (one
        compacted work list per shard; see
        :func:`repro.core.schedule.shard_schedule` / docs/DESIGN.md §5).
        A stacked [L, K, N] weight (:func:`knead_stacked`) shards per layer
        into a :class:`ShardedStackedKneadedWeight` (docs/DESIGN.md §8).
        ``partition="balanced"`` LPT-packs tiles on their static occupancy
        instead of contiguous slabs (docs/DESIGN.md §11)."""
        if self.planes.ndim > 4:
            raise ValueError(
                "expert banks ([..., E, K, N] stacks) are placed on the "
                "'expert' mesh axis, not N-sharded — see docs/DESIGN.md §13")
        if self.planes.ndim == 4:
            return shard_stacked_schedule(self, mesh, axis=axis,
                                          partition=partition)
        return shard_schedule(self, mesh, axis=axis, partition=partition)

    def work_table(self):
        """Static per-slice work totals: ``schedule.counts`` summed over the
        N-tile axis, as a host numpy array shaped like the stack's leading
        axes ([L, E] for an MoE bank, [L] for scan layers, scalar for a
        plain 2-D weight).  This is the ``layer_shard_work``-style input
        the routing-load / work-stealing accounting consumes: experts are
        naturally imbalanced work, and the table quantifies it without
        touching device data beyond the (tiny) counts array."""
        import numpy as np
        return np.asarray(self.schedule.counts).sum(axis=-1)

    def metadata_bytes(self) -> int:
        """Pass-mark metadata footprint: packed presence bits + the
        compacted schedule arrays the kernel prefetches."""
        return self.occupancy.size * 4 + self.schedule.metadata_bytes()

    def packed_bytes(self) -> int:
        """True HBM bytes of the kneaded format: packed planes + signs +
        scale + the full metadata footprint (:meth:`metadata_bytes`)."""
        return (
            self.planes.size * 4
            + self.signs.size * 4
            + self.scale.size * 4
            + self.metadata_bytes()
        )

    def dense_bf16_bytes(self) -> int:
        return self.k * self.n * 2


def kneadable_dims(k: int, n: int, ks: int = 256,
                   n_block: int = 128) -> Tuple[int, int]:
    """Smallest (K', N') >= (k, n) meeting the kneaded-format alignment:
    K' a multiple of lcm(32, ks) (bit-packing word AND kernel K tile),
    N' a multiple of n_block (kernel N tile)."""
    k_align = math.lcm(32, ks)
    return (-(-k // k_align) * k_align, -(-n // n_block) * n_block)


def knead(
    w: jax.Array,
    bits: int = 8,
    ks: int = 256,
    n_block: int = 128,
    *,
    qt: Optional[QuantizedTensor] = None,
) -> KneadedWeight:
    """Quantize (unless ``qt`` given) and knead a [K, N] weight matrix.

    K must be a multiple of lcm(32, ks); N a multiple of n_block.  Model dims
    in this framework are multiples of 128, so this holds by construction;
    for arbitrary dims (conv im2col matrices) use :func:`knead_padded`.
    """
    if qt is None:
        qt = quantize(w, bits=bits, axis=-1)
    q = qt.q
    if q.ndim != 2:
        raise ValueError(f"knead expects [K, N], got {q.shape}")
    k, n = q.shape
    if (k, n) != kneadable_dims(k, n, ks, n_block):
        raise ValueError(f"shape {q.shape} incompatible with ks={ks}, n_block={n_block}")
    mag = bitplanes.magnitude_planes(q, qt.bits)                # [B-1, K, N]
    planes = bitplanes.pack_bits(mag, axis=1)                   # [B-1, K/32, N]
    signs = bitplanes.pack_bits((q < 0).astype(jnp.uint8), axis=0)
    occ_map = bitplanes.plane_tile_occupancy(mag, ks, n_block)
    scale = qt.scale.reshape(1, -1) if qt.scale.ndim else qt.scale
    return KneadedWeight(
        planes=planes, signs=signs, scale=scale.astype(jnp.float32),
        occupancy=bitplanes.pack_presence(occ_map),
        schedule=build_schedule(occ_map),
        bits=qt.bits, ks=ks, n_block=n_block, k=k, n=n,
    ).with_checksums()


def knead_padded(
    w: jax.Array,
    bits: int = 8,
    ks: int = 256,
    n_block: int = 128,
) -> KneadedWeight:
    """Knead an arbitrarily-shaped [K, N] matrix by zero-padding to alignment.

    The conv path's im2col matrices have K = C*kh*kw (27, 576, 4800, ...),
    rarely a multiple of lcm(32, ks).  Zero padding is exact: padded rows
    multiply activations that are themselves zero-padded, padded output
    channels get scale 1.0 / codes 0 and are sliced off.  Both directions
    produce all-zero planes (occupancy 0) that the schedule never
    dispatches, so the padding costs metadata only, no MXU passes.
    ``logical_k``/``logical_n`` record the original dims for the dispatch
    layer.
    """
    if w.ndim != 2:
        raise ValueError(f"knead_padded expects [K, N], got {w.shape}")
    k0, n0 = w.shape
    kp, np_ = kneadable_dims(k0, n0, ks, n_block)
    if (kp, np_) != (k0, n0):
        w = jnp.pad(w, ((0, kp - k0), (0, np_ - n0)))
    kw = knead(w, bits=bits, ks=ks, n_block=n_block)
    if (kp, np_) == (k0, n0):
        return kw
    return dataclasses.replace(kw, k_orig=k0, n_orig=n0)


def knead_stacked(
    w: jax.Array,
    bits: int = 8,
    ks: int = 256,
    n_block: int = 128,
) -> KneadedWeight:
    """Knead a stacked weight with any leading stack axes, one slice at a
    time: [L, K, N] scan-layer weights, [E, K, N] MoE expert banks, and the
    combined [L, E, K, N] scan-layer expert banks all take this path.

    The LM stacks scan over layers with stacked params, so the serving form
    must slice per leading axis inside ``jax.lax.scan`` (an expert bank is
    sliced a second time, per local expert, inside the MoE dispatch).  Every
    slice is kneaded *independently* (its own per-out-channel scales,
    occupancy map, and compacted schedule — slice s's work lists are exactly
    what ``knead_padded(w[s])`` would build) and the resulting arrays stack
    with the leading stack axes: ``planes [*S, B-1, K/32, N]``, ``signs``,
    ``scale``, ``occupancy``, and the schedule's ``counts [*S, NN]`` /
    ``plane_ids``/``ktile_ids [*S, NN, num_work]``.  Scanning this pytree as
    ``xs`` hands the body slice s's :class:`KneadedWeight`, bit-identical to
    the unstacked knead of that slice.

    The work dimension is padded to the *max* ``num_work`` across slices by
    repeating each N-tile's last item — the same convention as intra-tile
    ragged padding, so padded grid steps re-request resident blocks and idle
    under the kernel's ``w < counts[j]`` guard.  A fully-empty slice (an
    expert pruned to all-zero weights) has no last item to repeat and pads
    with item 0 instead; its counts are all zero, so the guard masks every
    step.  Statics on the stacked weight: ``num_work`` is the cross-slice
    max and ``total_work`` the all-slice sum (a per-slice view therefore
    reports the stack totals — use :meth:`KneadedWeight.work_table` or
    :func:`knead_padded` per slice when per-slice accounting matters).
    """
    if w.ndim < 3:
        raise ValueError(
            f"knead_stacked expects [*stack, K, N] with >=1 stack axis, "
            f"got {w.shape}")
    lead = w.shape[:-2]
    flat = w.reshape((-1,) + w.shape[-2:])
    per_slice = [knead_padded(flat[s], bits=bits, ks=ks, n_block=n_block)
                 for s in range(flat.shape[0])]
    num_work = max(kw.schedule.num_work for kw in per_slice)

    def pad_work(ids: jax.Array, have: int) -> jax.Array:
        if have == num_work:
            return ids
        if have == 0:   # empty slice: no last item to repeat; counts==0
            return jnp.zeros((ids.shape[0], num_work), ids.dtype)
        return jnp.concatenate(
            [ids, jnp.repeat(ids[:, -1:], num_work - have, axis=1)], axis=1)

    def restack(xs):
        arr = jnp.stack(xs)
        return arr.reshape(lead + arr.shape[1:])

    first = per_slice[0]
    sched = KneadedSchedule(
        counts=restack([kw.schedule.counts for kw in per_slice]),
        plane_ids=restack([pad_work(kw.schedule.plane_ids,
                                    kw.schedule.num_work)
                           for kw in per_slice]),
        ktile_ids=restack([pad_work(kw.schedule.ktile_ids,
                                    kw.schedule.num_work)
                           for kw in per_slice]),
        num_work=num_work,
        total_work=sum(kw.schedule.total_work for kw in per_slice),
        nk=first.schedule.nk,
        n_tiles=first.schedule.n_tiles,
    )
    return dataclasses.replace(
        first,
        planes=restack([kw.planes for kw in per_slice]),
        signs=restack([kw.signs for kw in per_slice]),
        scale=restack([kw.scale for kw in per_slice]),
        occupancy=restack([kw.occupancy for kw in per_slice]),
        schedule=sched,
    ).with_checksums()     # re-stamp: slice-0 CRCs don't cover the stack


def reknead_like(kw: Union[KneadedWeight, ShardedKneadedWeight],
                 w_float: jax.Array,
                 shards: int = 0) -> Union[KneadedWeight,
                                           ShardedKneadedWeight]:
    """Repair path: rebuild a (possibly corrupted) kneaded weight from its
    float source, with the same knead geometry.

    Kneading is deterministic, so the rebuilt weight is bit-identical to the
    original knead of ``w_float`` — serving that repaired weight produces
    the same outputs as if the corruption never happened (the resilience
    layer's weight-repair guarantee, docs/DESIGN.md §10).  ``shards``
    re-shards stacked/2-D weights when the corrupt weight was sharded
    (pass the engine's shard count; 0/1 = unsharded).  Sharded rebuilds
    keep the original weight's ``partition`` mode — a balanced weight
    repairs to the identical LPT packing (deterministic on identical
    counts), so the repair stays bit-identical.
    """
    stacked = w_float.ndim >= 3
    fresh = (knead_stacked if stacked else knead_padded)(
        w_float, bits=kw.bits, ks=kw.ks, n_block=kw.n_block)
    if shards > 1 or isinstance(kw, ShardedKneadedWeight):
        num = shards if shards > 1 else kw.num_shards
        partition = getattr(kw, "partition", "contiguous")
        fresh = (shard_stacked_schedule if stacked
                 else shard_schedule)(fresh, num, partition=partition)
    return fresh


def kneaded_codes(kw: KneadedWeight) -> jax.Array:
    """Signed integer codes [K, N] reconstructed from the packed planes."""
    mag = bitplanes.unpack_bits(kw.planes, axis=1).astype(jnp.int32)  # [B-1,K,N]
    weights = (2 ** jnp.arange(kw.bits - 1, dtype=jnp.int32)).reshape(-1, 1, 1)
    absq = jnp.sum(mag * weights, axis=0)                             # [K, N]
    sign = 1 - 2 * bitplanes.unpack_bits(kw.signs, axis=0).astype(jnp.int32)
    return absq * sign


def unknead(kw: KneadedWeight) -> jax.Array:
    """Exact float reconstruction: equals dequantize(quantize(w)) of knead()."""
    return kneaded_codes(kw).astype(jnp.float32) * kw.scale
