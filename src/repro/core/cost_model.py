"""Cycle-accurate analytical cost model of DaDN / PRA / Tetris PEs.

The paper evaluates Tetris with Vivado HLS cycle simulation against two
baselines: DaDianNao (bit-parallel MAC, 1 pair/lane/cycle) and PRA
(bit-pragmatic: bit-serial over *activation* essential bits).  This module is
the analytical equivalent, driven by the measured bit statistics of real
quantized weights/activations — it reproduces Figs 8, 9, 10, 11.

Lane model (cycles per group of ``ks`` weight/activation pairs in one
reduction lane):

  DaDN   : ks                      (one MAC per cycle per lane)
  PRA    : max_i popcount(A_i) over groups of 16 concurrent bit-lanes,
           + PRA_STAGE_OVERHEAD    (the paper's multi-stage-shifter critique)
  Tetris : max_b popcount_b(group) (kneaded cycles, Fig 3)

int8 mode: the splitter halves double throughput for Tetris (paper §III.3);
DaDN's int8 comparison point likewise processes two 8-bit pairs per cycle.
All speedups are reported mode-to-mode (fp16 vs fp16, int8 vs int8), matching
the paper's Fig 8 normalization.

Energy: the paper measures average *power* ratios (PrimeTime): Tetris 1.08x
DaDN, PRA 3.37x DaDN.  We inherit those constants (we cannot synthesize) and
combine with modeled cycles:  EDP ∝ P * T^2  (Fig 10 uses EDP).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import bitplanes
from repro.core.kneading import kneaded_cycles

__all__ = [
    "POWER_RATIO",
    "CostBreakdown",
    "dadn_lane_cycles",
    "pra_lane_cycles",
    "tetris_lane_cycles",
    "model_layer",
    "edp",
]

# Average-power ratios normalized to DaDN, from the paper's PrimeTime
# measurements (§IV.B).  PRA pays 3.37x for 16x weight FIFOs.
POWER_RATIO: Dict[str, float] = {"dadn": 1.0, "pra": 3.37, "tetris": 1.08}

# PRA processes essential activation bits through a multi-stage shifter that
# "cannot be accomplished within one cycle" (paper §IV.A).  Extra cycles per
# 16-pair group; we inherit the paper's own PRA measurement by calibrating
# this constant so PRA-fp16 lands at the reported ~1.15x over DaDN on the
# CNN suite (benchmarks/bench_fig8) — the paper gives no finer-grained PRA
# pipeline data to model from first principles.
PRA_STAGE_OVERHEAD = 5
PRA_GROUP = 16  # concurrent bit-lanes in the PRA design


def _group(x: jax.Array, size: int) -> jax.Array:
    """[K, ...] -> [ceil(K/size), size, ...], zero-padding the ragged tail
    (zero codes contribute zero essential bits — exact for both models)."""
    k = x.shape[0]
    pad = (-k) % size
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x.reshape(((k + pad) // size, size) + x.shape[1:])


def dadn_lane_cycles(n_pairs: int, mode: str = "fp16") -> float:
    """Bit-parallel MAC baseline: one pair per cycle (two in int8 mode)."""
    return n_pairs / (2.0 if mode == "int8" else 1.0)


def pra_lane_cycles(act_codes: jax.Array, bits: int) -> jax.Array:
    """PRA: per 16-pair group, max over lanes of activation popcount."""
    mag = jnp.abs(act_codes.astype(jnp.int32)).reshape(-1)
    pc = bitplanes.popcount(mag)
    groups = _group(pc, PRA_GROUP)                      # [G, 16]
    return jnp.sum(jnp.max(groups, axis=1) + PRA_STAGE_OVERHEAD)


def tetris_lane_cycles(
    w_codes: jax.Array, bits: int, ks: int, mode: str = "fp16"
) -> jax.Array:
    """Tetris: kneaded cycles per KS-group (Fig 3), halved in int8 mode."""
    pad = (-w_codes.shape[0]) % ks
    if pad:   # zero weights knead away for free — exact padding
        w_codes = jnp.concatenate(
            [w_codes, jnp.zeros((pad,) + w_codes.shape[1:], w_codes.dtype)])
    cyc = kneaded_cycles(w_codes, bits, ks)             # [K/ks, ...]
    total = jnp.sum(cyc)
    return total / (2.0 if mode == "int8" else 1.0)


@dataclasses.dataclass
class CostBreakdown:
    """Modeled cycles for one layer under each scheme."""

    dadn: float
    pra: float
    tetris: float
    mode: str
    ks: int

    def speedup(self) -> Dict[str, float]:
        return {"pra": self.dadn / self.pra, "tetris": self.dadn / self.tetris}


def model_layer(
    w_codes: jax.Array,
    act_codes: jax.Array,
    bits: int,
    ks: int = 16,
    mode: str = "fp16",
) -> CostBreakdown:
    """Model one layer's lane cycles under DaDN / PRA / Tetris.

    Args:
      w_codes:   quantized weight codes [K, N] (K = reduction lane axis).
      act_codes: quantized activation codes, any shape (sampled lane inputs).
      bits:      16 for the paper's "fp16" fixed point, 8 for int8 mode.
    """
    kdim, n = w_codes.shape
    # Total pairs = K per output lane; model a representative lane set (all N).
    dadn = float(dadn_lane_cycles(kdim, mode)) * n
    pra = float(pra_lane_cycles(act_codes, bits)) / max(act_codes.size // kdim, 1)
    pra = pra * n  # same activation stream feeds every output lane
    tet = float(tetris_lane_cycles(w_codes, bits, ks, mode))
    return CostBreakdown(dadn=dadn, pra=float(pra), tetris=float(tet),
                         mode=mode, ks=ks)


def edp(cycles: float, scheme: str) -> float:
    """Energy-delay product ∝ power * time^2, normalized units."""
    return POWER_RATIO[scheme] * cycles * cycles
