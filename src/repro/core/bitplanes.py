"""Bit-plane decomposition and packing — the data layout under SAC.

The paper interprets fixed-point weights bit-by-bit (Fig 3) and routes
activations per essential bit (Fig 4/6).  The TPU-native equivalent is a
*sign-magnitude bit-plane decomposition*:

    q = sign(q) * |q|,   |q| = sum_b 2^b * P_b,   P_b in {0,1}

so that

    A @ (q * scale) = scale * sum_b 2^b * (A @ S_b),   S_b = sign(q) * P_b

Each ``S_b`` is a {-1, 0, 1} matrix — a *bit plane*.  The per-plane partial
products ``A @ S_b`` are the paper's *segment registers*; the single final
``sum_b 2^b`` is the *rear adder tree*.  Plane density directly measures the
paper's "essential bits": an all-zero plane tile is pure slack and is skipped
by the kernel (the kneading analogue).

Sign-magnitude (rather than two's complement) is chosen deliberately: for
bell-shaped weight distributions the high-magnitude planes are nearly empty,
while two's complement sign-extension would fill them with 1s for every
negative weight — destroying the very slack the paper harvests.

Packing: planes are bit-packed 32-per-word (uint32) along the *reduction*
axis K, so a B-bit kneaded weight matrix occupies ``B/16`` of its bf16 bytes
in HBM — the memory-roofline payoff for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "to_signed_planes",
    "from_signed_planes",
    "magnitude_planes",
    "pack_bits",
    "unpack_bits",
    "plane_tile_occupancy",
    "pack_presence",
    "unpack_presence",
    "popcount",
]

WORD = 32  # packing word width (uint32)


def popcount(x: jax.Array) -> jax.Array:
    """Number of set bits, elementwise (int32 result)."""
    return jax.lax.population_count(x.astype(jnp.uint32)).astype(jnp.int32)


def magnitude_planes(q: jax.Array, bits: int) -> jax.Array:
    """Unsigned magnitude planes: P[b] = bit b of |q|.

    Args:
      q: integer codes, any signed int dtype; |q| must fit in ``bits - 1`` bits.
    Returns:
      uint8 array of shape ``(bits - 1,) + q.shape`` with values in {0, 1}.
    """
    mag = jnp.abs(q.astype(jnp.int32))
    shifts = jnp.arange(bits - 1, dtype=jnp.int32).reshape(
        (bits - 1,) + (1,) * q.ndim
    )
    return ((mag[None] >> shifts) & 1).astype(jnp.uint8)


def to_signed_planes(q: jax.Array, bits: int) -> jax.Array:
    """Signed planes S[b] = sign(q) * bit b of |q|, values in {-1, 0, 1}.

    Satisfies ``q == sum_b 2**b * S[b]`` exactly (int arithmetic).
    """
    planes = magnitude_planes(q, bits).astype(jnp.int8)
    sign = jnp.sign(q.astype(jnp.int32)).astype(jnp.int8)
    return planes * sign[None]


def from_signed_planes(planes: jax.Array) -> jax.Array:
    """Inverse of :func:`to_signed_planes` (int32 codes)."""
    b = planes.shape[0]
    weights = (2 ** jnp.arange(b, dtype=jnp.int32)).reshape((b,) + (1,) * (planes.ndim - 1))
    return jnp.sum(planes.astype(jnp.int32) * weights, axis=0)


def pack_bits(bits01: jax.Array, axis: int = 0) -> jax.Array:
    """Pack a {0,1} array into uint32 words along ``axis``.

    ``axis`` length must be a multiple of 32 (pad upstream).  Bit ``i`` of the
    word holds element ``word_index * 32 + i`` (little-endian within word).
    """
    axis = axis % bits01.ndim
    n = bits01.shape[axis]
    if n % WORD != 0:
        raise ValueError(f"pack axis length {n} not a multiple of {WORD}")
    x = jnp.moveaxis(bits01.astype(jnp.uint32), axis, -1)
    x = x.reshape(x.shape[:-1] + (n // WORD, WORD))
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    packed = jnp.sum(x << shifts, axis=-1, dtype=jnp.uint32)
    return jnp.moveaxis(packed, -1, axis)


def unpack_bits(packed: jax.Array, axis: int = 0) -> jax.Array:
    """Inverse of :func:`pack_bits`; returns uint8 {0,1} with axis length *32."""
    axis = axis % packed.ndim
    x = jnp.moveaxis(packed.astype(jnp.uint32), axis, -1)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits01 = ((x[..., None] >> shifts) & 1).astype(jnp.uint8)
    bits01 = bits01.reshape(x.shape[:-1] + (x.shape[-1] * WORD,))
    return jnp.moveaxis(bits01, -1, axis)


def plane_tile_occupancy(
    planes: jax.Array, k_block: int, n_block: int
) -> jax.Array:
    """Per (plane, K-tile, N-tile) occupancy: does any essential bit exist?

    Args:
      planes: {0,1} or {-1,0,1} planes of shape [B, K, N].
      k_block, n_block: kernel tile extents (K % k_block == N % n_block == 0).
    Returns:
      int32 [B, K//k_block, N//n_block], 1 where the tile has >=1 essential bit.

    This is the TPU analogue of the paper's pass-mark/throttle metadata: the
    kernel consults it (scalar prefetch) and skips slack-only tiles.
    """
    b, k, n = planes.shape
    if k % k_block or n % n_block:
        raise ValueError(f"({k},{n}) not divisible by ({k_block},{n_block})")
    t = jnp.abs(planes.astype(jnp.int32)).reshape(
        b, k // k_block, k_block, n // n_block, n_block
    )
    return (jnp.sum(t, axis=(2, 4)) > 0).astype(jnp.int32)


def pack_presence(presence: jax.Array) -> jax.Array:
    """Bit-pack a {0,1} presence map along its K-tile axis.

    Args:
      presence: {0,1} [B, NK, NN] (e.g. :func:`plane_tile_occupancy` output).
    Returns:
      uint32 [B, ceil(NK/32), NN] — axis 1 zero-padded to a word multiple and
      packed little-endian.  One *bit* per (plane, K-tile, N-tile) instead of
      an int32 entry: the stored pass-mark metadata shrinks 32x.
    """
    b, nk, nn = presence.shape
    pad = (-nk) % WORD
    if pad:
        presence = jnp.pad(presence, ((0, 0), (0, pad), (0, 0)))
    return pack_bits((presence != 0).astype(jnp.uint8), axis=1)


def unpack_presence(packed: jax.Array, nk: int) -> jax.Array:
    """Inverse of :func:`pack_presence`: uint32 words -> int32 {0,1} [B, nk, NN]."""
    return unpack_bits(packed, axis=1)[:, :nk].astype(jnp.int32)
