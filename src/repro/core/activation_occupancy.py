"""Runtime activation-side occupancy — the two-sided skip (docs/DESIGN.md §12).

Tetris kneads slack out of the *weight* side at knead time: the compacted
:class:`~repro.core.schedule.KneadedSchedule` is static — built once from the
weight occupancy map, walked unchanged every step.  The activation side is
*dynamically* sparse (Cnvlutin2 / Laconic in PAPERS.md): a ReLU trace or an
MoE residual can zero whole reduction ranges, and a work item whose
activation K-slice is all zero contributes exactly ``A_t @ P_bt == 0`` no
matter which bit plane it names.

This module is the runtime half of the intersection.  Per SAC call it

1. computes per-K-tile presence bits from the activation block
   (:func:`ktile_presence` — a reshape + ``any``, one pass over the
   single decode row, unioned over rows for micro-batches),
2. intersects them with the weight-side schedule to produce a per-work-item
   survival mask (:func:`work_mask`) the Pallas kernel consumes as a fourth
   scalar-prefetch operand, and
3. accounts executed vs weight-only tile-dots (:func:`record_skip` /
   :func:`skip_stats`) so ``latency_stats()`` and the bench can report
   ``act_skip_frac`` honestly.

Bit-exactness argument (why masking cannot change the output): the mask only
*drops* items whose activation slice is identically zero, and dropped items
would have added exactly ``+0.0`` to their f32 segment accumulator.  Adding
0.0 is a bitwise no-op on every finite f32 (and on the parity tests'
``assert_array_equal``, where ``-0.0 == +0.0``), and surviving items keep
their relative k-major order, so per-segment accumulation sequences are
operation-for-operation identical to the unmasked walk.  Hence
``pallas(skip) == pallas == planes`` bit-for-bit — the property wall in
``tests/test_schedule.py`` pins all three.

The packed-word form (:func:`intersect_packed_presence`) is the metadata
view of the same intersection: weight presence words AND the broadcast
activation presence words, per bit plane — its popcount equals the work
surviving the mask, which the property tests also pin.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplanes

__all__ = [
    "ktile_presence",
    "work_mask",
    "weight_only_mask",
    "intersect_packed_presence",
    "record_skip",
    "skip_stats",
    "reset_skip_stats",
    "GEMV_ROWS_MAX",
]

# The decode-GEMV gate: activation skip engages only when the flattened
# activation has at most this many rows (one f32 sublane).  A decode step is
# M = batch <= 8 here; prefill (M = batch * seq) falls back to the static
# weight-only schedule — a union of presence over hundreds of rows is all
# ones, so masking would add runtime cost for zero skipped work.
GEMV_ROWS_MAX = 8


def ktile_presence(a: jax.Array, ks: int) -> jax.Array:
    """Per-K-tile activation presence: int32 [K // ks] in {0, 1}.

    ``presence[t] = any(a[:, t*ks:(t+1)*ks] != 0)`` — the union over the
    (GEMV-few) rows of ``a``.  A tile is absent only when *every* row's
    slice is zero, which is exactly the condition under which dropping the
    tile's work items is a bitwise no-op for every row of the output.

    ``a`` must already be padded to the stored (tile-aligned) K; padding
    columns are zero and never flip a presence bit.
    """
    m, k = a.shape
    if k % ks:
        raise ValueError(f"activation K {k} not divisible by ks={ks}")
    tiles = a.reshape(m, k // ks, ks)
    return jnp.any(tiles != 0, axis=(0, 2)).astype(jnp.int32)


def weight_only_mask(counts: jax.Array, num_work: int) -> jax.Array:
    """The static schedule's own survival mask: int32 [n_tiles, num_work],
    1 for real work items (``w < counts[j]``), 0 for the idle padding tail.
    This is what the kernel guard tested before activation skip existed —
    passing it reproduces the weight-only walk bit-for-bit."""
    w = jax.lax.broadcasted_iota(jnp.int32, (counts.shape[0], num_work), 1)
    return (w < counts[:, None]).astype(jnp.int32)


def work_mask(counts: jax.Array, ktile_ids: jax.Array,
              act_presence: Optional[jax.Array]) -> jax.Array:
    """Survival mask over schedule slots: int32 [n_tiles, num_work].

    Slot (j, w) survives iff it is a real item (``w < counts[j]``) AND the
    activation K-tile it names is present.  With ``act_presence=None`` this
    degrades to :func:`weight_only_mask` — the masked kernel then executes
    exactly the pre-skip walk.  Monotone by construction: the intersected
    mask is pointwise <= the weight-only mask (work ⊆ weight-only work),
    and surviving slots keep their k-major slot positions, preserving the
    per-segment f32 accumulation order the bit-exactness proof needs.
    """
    base = weight_only_mask(counts, ktile_ids.shape[-1])
    if act_presence is None:
        return base
    alive = (act_presence[ktile_ids] != 0).astype(jnp.int32)
    return base * alive


def intersect_packed_presence(occupancy: jax.Array,
                              act_presence: jax.Array) -> jax.Array:
    """AND activation presence into the weight-side packed presence words.

    ``occupancy`` is the kneaded format's uint32 [B-1, ceil(nk/32), NN]
    pass-mark metadata (1 bit per (plane, K-tile, N-tile)); the activation
    contributes one bit per K-tile, broadcast over planes and N-tiles.
    Returns the intersected words, same shape/dtype.  Its per-(plane, tile)
    popcount equals the surviving work count of :func:`work_mask` — the
    metadata-level and schedule-level views of the same skip, which the
    property suite pins against each other.
    """
    nk = act_presence.shape[0]
    act_words = bitplanes.pack_presence(
        act_presence.reshape(1, nk, 1))          # [1, ceil(nk/32), 1]
    return occupancy & act_words


# ---------------------------------------------------------------------------
# Skip accounting — executed vs weight-only tile-dots, per process
# ---------------------------------------------------------------------------
# The counters live module-level because the interesting callers are jitted
# (the engine's decode step): a ``jax.debug.callback`` fires at *runtime*
# inside the traced computation and folds each launch's (executed,
# weight-only) pair into this accumulator.  Engines snapshot at init and
# report deltas, so concurrent engines see their own traffic plus any
# overlapping peer's — fine for serving stats, and the tests use
# :func:`reset_skip_stats` for exact accounting.

_LOCK = threading.Lock()
_EXECUTED = 0
_WEIGHT_ONLY = 0
_CALLS = 0


def _accumulate(executed, weight_only) -> None:
    global _EXECUTED, _WEIGHT_ONLY, _CALLS
    with _LOCK:
        _EXECUTED += int(np.asarray(executed))
        _WEIGHT_ONLY += int(np.asarray(weight_only))
        _CALLS += 1


def record_skip(mask: jax.Array, counts: jax.Array) -> None:
    """Fold one masked launch into the process-wide skip counters.

    Call inside the jitted wrapper, right where the mask is built:
    ``executed = mask.sum()`` (surviving tile-dots this launch) and
    ``weight_only = counts.sum()`` (what the static schedule would have
    run).  Shapes are static so the sums fuse into the step; the callback
    is the only host hop and fires once per launch.
    """
    jax.debug.callback(_accumulate,
                       jnp.sum(mask.astype(jnp.int32)),
                       jnp.sum(counts.astype(jnp.int32)))


def skip_stats() -> Dict[str, float]:
    """Snapshot of the process-wide skip counters.

    Returns ``executed_tile_dots``, ``weight_tile_dots``, ``skip_calls``
    and the derived ``act_skip_frac = 1 - executed / weight_only`` (0.0
    when nothing was recorded).  Flushes pending debug callbacks first so a
    read after ``drain()`` sees every decode step's launch.
    """
    jax.effects_barrier()
    with _LOCK:
        executed, weight_only, calls = _EXECUTED, _WEIGHT_ONLY, _CALLS
    frac = 1.0 - executed / weight_only if weight_only else 0.0
    return {
        "executed_tile_dots": executed,
        "weight_tile_dots": weight_only,
        "skip_calls": calls,
        "act_skip_frac": frac,
    }


def reset_skip_stats() -> None:
    """Zero the process-wide counters (test isolation)."""
    global _EXECUTED, _WEIGHT_ONLY, _CALLS
    jax.effects_barrier()
    with _LOCK:
        _EXECUTED = 0
        _WEIGHT_ONLY = 0
        _CALLS = 0
