"""Process-global MoE routing-load accounting (docs/DESIGN.md §13).

The MoE dispatch records, from inside jitted prefill/decode steps, how many
(token, expert) assignments each step actually executed and how many were
dropped by the capacity limit (``mypos >= cap`` in the slot routing).  The
counters are process-global — accumulated via ``jax.debug.callback`` exactly
like the activation-skip accounting in
:mod:`repro.core.activation_occupancy` — so each serving engine snapshots a
baseline at construction and reports its own delta in ``latency_stats()``.

Together with the static per-expert work table
(:meth:`repro.core.kneading.KneadedWeight.work_table`) this is the input the
ROADMAP work-stealing item needs: the table says how much kneaded work each
expert *owns*, the counters say how much traffic routing actually *sends*.
"""
from __future__ import annotations

import threading
from typing import Dict

import jax
import jax.numpy as jnp

_LOCK = threading.Lock()
_ROUTED = 0          # (token, expert) assignments executed (within capacity)
_DROPPED = 0         # assignments dropped by the capacity limit
_STEPS = 0           # routed MoE layer applications recorded


def _accumulate(routed, dropped) -> None:
    global _ROUTED, _DROPPED, _STEPS
    with _LOCK:
        _ROUTED += int(routed)
        _DROPPED += int(dropped)
        _STEPS += 1


def record_routing(eids: jax.Array, num_experts: int, cap: int) -> None:
    """Record one MoE layer's routing load.  Call from inside jit.

    ``eids`` [T, k] are the (replicated) global expert assignments; drops
    are derived from the per-expert histogram — expert e drops
    ``max(0, count_e - cap)`` assignments, exactly the ``mypos >= cap``
    overflow of the slot routing (position within an expert is global
    arrival order, so the histogram form is equivalent and O(T*k + E)
    instead of O(T*k*E)).
    """
    flat_e = eids.reshape(-1)
    counts = jnp.zeros((num_experts,), jnp.int32).at[flat_e].add(1)
    dropped = jnp.sum(jnp.maximum(counts - cap, 0))
    routed = flat_e.shape[0] - dropped
    jax.debug.callback(_accumulate, routed, dropped)


def routing_stats() -> Dict[str, int]:
    """Cumulative routing-load counters (flushes pending callbacks)."""
    jax.effects_barrier()
    with _LOCK:
        return {"routed_tokens": _ROUTED,
                "capacity_dropped": _DROPPED,
                "routing_steps": _STEPS}


def reset_routing_stats() -> None:
    global _ROUTED, _DROPPED, _STEPS
    jax.effects_barrier()
    with _LOCK:
        _ROUTED = _DROPPED = _STEPS = 0
