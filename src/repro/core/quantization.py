"""Fixed-point quantization — the substrate under weight kneading.

The paper quantizes fp32 Caffe weights to fixed-point-16 ("fp16" in the
paper's nomenclature) and int8, then fine-tunes.  We implement symmetric
per-output-channel fixed-point quantization for B in {2..16} bits.

Conventions
-----------
* ``q`` is a signed integer code in ``[-(2^{B-1}-1), 2^{B-1}-1]`` stored in the
  smallest sufficient integer dtype (int8 for B<=8 else int16/int32).
* ``w ~= q * scale`` with ``scale`` broadcast along the *output-channel* axis
  (last axis by convention: weights are stored ``[..., K, N]`` and channel = N).
* We deliberately exclude ``-2^{B-1}`` from the code range so that ``|q|`` fits
  in B-1 magnitude bits — this keeps the sign-magnitude bit-plane
  decomposition (`bitplanes.py`) exactly B-1 planes + sign, mirroring the
  paper's fixed-point layout.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "QuantizedTensor",
    "storage_dtype",
    "quantize",
    "dequantize",
    "fake_quantize",
]


def storage_dtype(bits: int) -> jnp.dtype:
    """Smallest signed integer dtype that can hold a ``bits``-bit code."""
    if bits <= 8:
        return jnp.int8
    if bits <= 16:
        return jnp.int16
    return jnp.int32


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """A symmetric fixed-point tensor: ``value ~= q.astype(f32) * scale``.

    Attributes:
      q:     integer codes, shape ``shape``.
      scale: f32 scales, broadcastable against ``q`` (per-channel on ``axis``).
      bits:  static bit width B (includes the sign bit).
      axis:  static channel axis the scales follow.
    """

    q: jax.Array
    scale: jax.Array
    bits: int = dataclasses.field(metadata=dict(static=True), default=8)
    axis: int = dataclasses.field(metadata=dict(static=True), default=-1)

    @property
    def shape(self):
        return self.q.shape

    @property
    def dtype(self):
        return self.q.dtype

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return dequantize(self, dtype)


def _channel_absmax(w: jax.Array, axis) -> jax.Array:
    """abs-max reduced over every axis except ``axis`` (kept, broadcastable).
    ``axis=None`` -> per-tensor scale (one fixed-point format for the whole
    matrix — the paper's 2018-accelerator setting; per-channel scales
    normalize each channel to the full code range and hide bit-level slack).
    """
    if axis is None:
        return jnp.max(jnp.abs(w)).reshape((1,) * w.ndim)
    reduce_axes = tuple(i for i in range(w.ndim) if i != axis % w.ndim)
    return jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)


def quantize(
    w: jax.Array,
    bits: int = 8,
    axis: int = -1,
    *,
    scale: Optional[jax.Array] = None,
    reduce_axes=None,
) -> QuantizedTensor:
    """Symmetric per-channel quantization of ``w`` to ``bits`` bits.

    ``axis`` is the channel axis (the output-feature axis for weight
    matrices); one scale per channel.  Pass ``scale`` to reuse a calibrated
    scale (e.g. when re-quantizing fine-tuned weights).  ``reduce_axes``
    restricts the abs-max reduction (e.g. ``(-2,)`` for stacked [L, K, N]
    weights: one scale per (layer, channel) instead of per channel).
    """
    if not 2 <= bits <= 16:
        raise ValueError(f"bits must be in [2, 16], got {bits}")
    w = w.astype(jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    if scale is None:
        if reduce_axes is not None:
            absmax = jnp.max(jnp.abs(w), axis=tuple(reduce_axes),
                             keepdims=True)
        else:
            absmax = _channel_absmax(w, axis)
        # Guard all-zero channels: scale 1.0 yields q == 0 there.
        scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax)
    return QuantizedTensor(
        q=q.astype(storage_dtype(bits)), scale=scale.astype(jnp.float32),
        bits=bits, axis=axis,
    )


def dequantize(t: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    return (t.q.astype(jnp.float32) * t.scale).astype(dtype)


def fake_quantize(w: jax.Array, bits: int = 8, axis: int = -1) -> jax.Array:
    """Quantize-dequantize round trip (for quantization-aware fine-tuning,
    the paper's §IV accuracy-recovery step) with a straight-through estimator
    so gradients flow to ``w`` unchanged."""
    qdq = dequantize(quantize(w, bits=bits, axis=axis), jnp.float32)
    w32 = w.astype(jnp.float32)
    return (w32 + jax.lax.stop_gradient(qdq - w32)).astype(w.dtype)
